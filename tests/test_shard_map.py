"""Multi-device parity tier: shard_map-wrapped fastmax kernels and the
sharding-aware chunked scan vs their single-device oracles.

Runs on 8 forced host CPU devices — `make test-shard` sets
REPRO_TEST_DEVICES=8 so tests/conftest.py injects
`--xla_force_host_platform_device_count=8` before jax initializes; in a
normal 1-device session every test here skips (the full gate covers them
through the subprocess wrapper in test_sharding.py).

Covered:
  * forward + emitted-state parity of the shard_map prefill kernel, both
    partitionings (heads mode, feature mode), p ∈ {1,2}, GQA;
  * 256-step decode: the shard_map fused decode kernel stays in lockstep
    with the single-device kernel;
  * backward parity of the shard_map trainable kernel vs the single-device
    kernel and vs the REPRO_FASTMAX_BWD=jnp §2.5 oracle, f64/f32/bf16 —
    heads mode (fused Pallas bwd applied per kv-head shard) AND feature
    mode (Dv-blocked bwd per value-feature shard, partial dq/dk psummed
    once per launch), including the end-to-end attention() routing proof
    that feature-TP training lands on shard_map[feature];
  * grad equivalence of the feature-TP sharding-aware chunked scan on a
    train-shaped toy vs the unsharded jnp oracle, f32/bf16 (kept on the
    scan path via REPRO_FASTMAX_BWD=jnp — the kernel-route escape hatch);
  * the decode-state sharding policy (moments + KV cache) matches the
    kernel ShardPlan partitioning.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

pytestmark = pytest.mark.shard


def mk(rng, b, hq, hkv, n, d, dv, dtype):
    from repro.core.ref import normalize_qk
    q = normalize_qk(jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype))
    k = normalize_qk(jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype))
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


def _mesh(shape):
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(shape, ("data", "model"))


# (mesh shape, hkv, hq) per partitioning mode: heads needs Hkv % tp == 0,
# feature exercises GQA/MQA kv heads that do NOT divide the model axis
MODES = {
    "heads": dict(mesh=(2, 4), hkv=4, hq=8),
    "feature": dict(mesh=(2, 4), hkv=2, hq=4),
    "heads_tp2": dict(mesh=(4, 2), hkv=2, hq=8),
}


def _plan_for(mesh, q, k, v):
    from repro.kernels.sharded import plan_kernel_sharding
    plan = plan_kernel_sharding(mesh, batch=q.shape[0], hq=q.shape[1],
                                hkv=k.shape[1], dv=v.shape[-1])
    assert plan is not None
    return plan


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("p", [1, 2])
def test_sharded_prefill_matches_single_device(shard_devices, mode, p):
    """Forward outputs AND the kernel-emitted final carry are identical
    between the shard_map launch and the single-device kernel."""
    from repro.kernels.ops import fastmax_prefill_kernel
    from repro.kernels.sharded import fastmax_prefill_sharded

    cfgm = MODES[mode]
    rng = np.random.default_rng(hash((mode, p)) % 2**31)
    q, k, v = mk(rng, 4, cfgm["hq"], cfgm["hkv"], 40, 4, 8, jnp.float64)
    o_ref, st_ref = fastmax_prefill_kernel(q, k, v, p=p, chunk_size=16)

    mesh = _mesh(cfgm["mesh"])
    with mesh:
        plan = _plan_for(mesh, q, k, v)
        assert plan.mode == ("feature" if mode == "feature" else "heads")
        o_sh, st_sh = fastmax_prefill_sharded(
            q, k, v, p=p, chunk_size=16, denom_eps=1e-6, plan=plan)
    np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_ref),
                               rtol=1e-12, atol=1e-12)
    for a, b in zip(st_sh, st_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("mode", ["heads", "feature"])
@pytest.mark.parametrize("p", [1, 2])
def test_sharded_decode_256_steps_lockstep(shard_devices, mode, p):
    """Prefill + 256 fused decode steps: the shard_map kernel state stays
    bit-for-bit with the single-device kernel over a long horizon."""
    from repro.kernels.ops import fastmax_decode, fastmax_prefill_kernel
    from repro.kernels.sharded import fastmax_decode_sharded

    cfgm = MODES[mode]
    rng = np.random.default_rng(7 + p)
    b, hq, hkv, d, dv = 2, cfgm["hq"], cfgm["hkv"], 4, 8
    q, k, v = mk(rng, b, hq, hkv, 16, d, dv, jnp.float64)
    _, st = fastmax_prefill_kernel(q, k, v, p=p, chunk_size=8)
    st_ref = tuple(st)
    st_sh = tuple(st)

    mesh = _mesh(cfgm["mesh"])
    with mesh:
        plan = _plan_for(mesh, q, k, v)
        step_sh = jax.jit(lambda q, k, v, st: fastmax_decode_sharded(
            q, k, v, st, p=p, denom_eps=1e-6, plan=plan))
        for i in range(256):
            q1, k1, v1 = mk(rng, b, hq, hkv, 1, d, dv, jnp.float64)
            o_ref, st_ref = fastmax_decode(q1, k1, v1, st_ref, p=p)
            o_sh, st_sh = step_sh(q1, k1, v1, tuple(st_sh))
            if i % 64 == 63:
                np.testing.assert_allclose(np.asarray(o_sh),
                                           np.asarray(o_ref),
                                           rtol=1e-12, atol=1e-12)
    for a, b in zip(st_sh, st_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("mode", ["heads", "heads_tp2"])
@pytest.mark.parametrize("p", [1, 2])
def test_sharded_trainable_backward_matches_single_device(shard_devices,
                                                          mode, p):
    """Grads through the shard_map trainable kernel (fused Pallas backward
    per shard) == grads through the single-device kernel, f64."""
    from repro.kernels.ops import fastmax
    from repro.kernels.sharded import fastmax_sharded

    cfgm = MODES[mode]
    rng = np.random.default_rng(hash((mode, p, "bwd")) % 2**31)
    q, k, v = mk(rng, 4, cfgm["hq"], cfgm["hkv"], 33, 4, 8, jnp.float64)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=p, causal=True,
                                       chunk_size=16)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)

    mesh = _mesh(cfgm["mesh"])
    with mesh:
        plan = _plan_for(mesh, q, k, v)
        assert plan.mode == "heads"

        def loss_sh(q, k, v):
            return jnp.sum(jnp.sin(fastmax_sharded(
                q, k, v, p=p, causal=True, chunk_size=16, denom_eps=1e-6,
                plan=plan)))

        g_sh = jax.grad(loss_sh, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_sh, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-11)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_sharded_kernel_grads_vs_jnp_oracle(shard_devices, monkeypatch,
                                            dtype, tol):
    """Heads-mode shard_map kernel grads vs the unsharded
    REPRO_FASTMAX_BWD=jnp §2.5 oracle, low precision."""
    from repro.kernels.sharded import fastmax_sharded

    rng = np.random.default_rng(23)
    q, k, v = mk(rng, 2, 8, 4, 48, 4, 8, dtype)

    monkeypatch.setenv("REPRO_FASTMAX_BWD", "jnp")
    from repro.kernels.ops import fastmax

    def loss_oracle(q, k, v):
        return jnp.sum(fastmax(q, k, v, p=2, causal=True, chunk_size=16))

    g_ref = jax.grad(loss_oracle, argnums=(0, 1, 2))(q, k, v)
    monkeypatch.delenv("REPRO_FASTMAX_BWD")

    mesh = _mesh((2, 4))
    with mesh:
        plan = _plan_for(mesh, q, k, v)

        def loss_sh(q, k, v):
            return jnp.sum(fastmax_sharded(q, k, v, p=2, causal=True,
                                           chunk_size=16, denom_eps=1e-6,
                                           plan=plan))

        g_sh = jax.grad(loss_sh, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_sh, g_ref):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)
        assert rel <= tol, f"rel err {rel} > {tol}"


@pytest.mark.parametrize("p", [1, 2])
def test_sharded_feature_trainable_backward_matches_single_device(
        shard_devices, p):
    """Feature mode TRAINING: grads through the shard_map trainable kernel
    (Dv-blocked fused backward per value-feature shard, one psum of the
    partial dq/dk per launch) == grads through the single-device kernel,
    f64."""
    from repro.kernels.ops import fastmax
    from repro.kernels.sharded import fastmax_sharded

    cfgm = MODES["feature"]
    rng = np.random.default_rng(hash(("feat-bwd", p)) % 2**31)
    q, k, v = mk(rng, 4, cfgm["hq"], cfgm["hkv"], 33, 4, 8, jnp.float64)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=p, causal=True,
                                       chunk_size=16)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)

    mesh = _mesh(cfgm["mesh"])
    with mesh:
        plan = _plan_for(mesh, q, k, v)
        assert plan.mode == "feature"

        def loss_sh(q, k, v):
            return jnp.sum(jnp.sin(fastmax_sharded(
                q, k, v, p=p, causal=True, chunk_size=16, denom_eps=1e-6,
                plan=plan)))

        o_sh = fastmax_sharded(q, k, v, p=p, causal=True, chunk_size=16,
                               denom_eps=1e-6, plan=plan)
        o_ref = fastmax(q, k, v, p=p, causal=True, chunk_size=16)
        np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_ref),
                                   rtol=1e-12, atol=1e-12)
        g_sh = jax.grad(loss_sh, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_sh, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-11)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_feature_tp_training_routes_to_sharded_kernel(shard_devices,
                                                      monkeypatch, dtype,
                                                      tol):
    """End to end through attention(): feature-TP TRAINING (kv heads don't
    divide 'model') now routes to the shard_map[feature] Dv-blocked
    kernels — the routing log proves it (no chunked-scan fallback) — and
    the grads match the unsharded REPRO_FASTMAX_BWD=jnp §2.5 oracle."""
    from repro.attention import AttentionSpec, attention
    from repro.attention import registry as _reg

    spec = AttentionSpec(family="fastmax", p=2, impl="kernel", chunk_size=16)
    rng = np.random.default_rng(37)
    q, k, v = mk(rng, 4, 4, 2, 64, 4, 8, dtype)

    monkeypatch.setenv("REPRO_FASTMAX_BWD", "jnp")

    def loss(q, k, v):
        return jnp.sum(attention(q, k, v, spec, causal=True))

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    monkeypatch.delenv("REPRO_FASTMAX_BWD")

    mesh = _mesh((2, 4))
    with mesh:
        _reg._LOGGED.clear()   # _log_once dedups across tests
        g_sh = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        new_logs = set(_reg._LOGGED)
    assert any("shard_map[feature]" in m for m in new_logs), new_logs
    assert not any("-> chunked scan" in m or "-> jnp" in m
                   for m in new_logs), new_logs
    for a, b in zip(g_sh, g_ref):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)
        assert rel <= tol, f"rel err {rel} > {tol}"


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_feature_tp_scan_grads_match_unsharded_oracle(shard_devices,
                                                      monkeypatch, dtype,
                                                      tol):
    """Satellite: the sharding-aware chunked scan under a feature-TP mesh
    (kv heads don't divide 'model'; stacked chunks pinned, carry
    constrained) produces the same grads as the unsharded jnp oracle
    on a train-shaped toy. REPRO_FASTMAX_BWD=jnp stays set for the mesh
    eval too: since the Dv-blocked backward landed, that env var is what
    keeps feature-TP training on the scan path (the default routes to the
    shard_map[feature] kernels — covered by
    test_feature_tp_training_routes_to_sharded_kernel)."""
    from repro.attention import AttentionSpec, attention

    spec = AttentionSpec(family="fastmax", p=2, impl="kernel", chunk_size=16)
    rng = np.random.default_rng(31)
    # train-shaped toy: batch over 'data', kv heads NOT divisible by tp=4
    q, k, v = mk(rng, 4, 4, 2, 64, 4, 8, dtype)

    monkeypatch.setenv("REPRO_FASTMAX_BWD", "jnp")

    def loss(q, k, v):
        return jnp.sum(attention(q, k, v, spec, causal=True))

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    mesh = _mesh((2, 4))
    with mesh:
        from repro.attention.api import feature_shard_flag
        from repro.attention import registry as _reg
        assert feature_shard_flag(k.shape[1])  # 2 % 4 != 0 -> feature-TP
        _reg._LOGGED.clear()   # _log_once dedups across tests
        g_sh = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        # the env var must have kept this on the scan path
        assert any("-> chunked scan" in m for m in set(_reg._LOGGED))
    for a, b in zip(g_sh, g_ref):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)
        assert rel <= tol, f"rel err {rel} > {tol}"


@pytest.mark.parametrize("mode", ["heads", "feature"])
def test_state_protocol_routes_sharded_kernel(shard_devices, monkeypatch,
                                              mode):
    """End to end through repro.attention prefill/step under a mesh with
    REPRO_DECODE_KERNEL=1: routed to the shard_map kernels (no jnp-fallback
    log) and numerically equal to full causal attention."""
    import dataclasses

    from repro.attention import AttentionSpec, attention, init_state
    from repro.attention import prefill as a_prefill
    from repro.attention import step as a_step
    from repro.attention import registry as _reg

    monkeypatch.setenv("REPRO_DECODE_KERNEL", "1")
    cfgm = MODES[mode]
    spec = AttentionSpec(family="fastmax", p=2, impl="kernel", chunk_size=8)
    rng = np.random.default_rng(5)
    b, hq, hkv, n, d, dv = 2, cfgm["hq"], cfgm["hkv"], 21, 4, 8
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), jnp.float64)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float64)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), jnp.float64)
    full = attention(q, k, v, dataclasses.replace(spec, impl="oracle"),
                     causal=True)

    mesh = _mesh(cfgm["mesh"])
    with mesh:
        st = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                        v_head_dim=dv, max_len=n, dtype=jnp.float64)
        pre = 13
        before = set(_reg._LOGGED)
        o_pre, st = a_prefill(q[:, :, :pre], k[:, :, :pre], v[:, :, :pre],
                              spec, state=st)
        outs = [o_pre]
        for t in range(pre, n):
            o_t, st = a_step(st, q[:, :, t:t + 1], k[:, :, t:t + 1],
                             v[:, :, t:t + 1], spec)
            outs.append(o_t)
        new_logs = set(_reg._LOGGED) - before
    assert any("shard_map" in m for m in new_logs), new_logs
    assert not any("-> jnp" in m for m in new_logs), new_logs
    got = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-8, atol=1e-9)


def test_decode_state_shardings_match_kernel_plan(shard_devices):
    """The committed inter-step state layout == the shard_map kernel
    partitioning, for both modes; KV caches are head- or sequence-sharded,
    never on head_dim."""
    from jax.sharding import PartitionSpec as P

    from repro.attention import AttentionSpec, init_state
    from repro.sharding.rules import decode_state_shardings

    mesh = _mesh((2, 4))

    def specs(hkv, family="fastmax"):
        spec = AttentionSpec() if family == "fastmax" else \
            AttentionSpec(family="softmax")
        st = jax.eval_shape(lambda: init_state(
            spec, batch=4, n_kv_heads=hkv, q_head_dim=8, v_head_dim=8,
            max_len=64))
        return decode_state_shardings(st, mesh, batch=4)

    # heads mode: Hkv=4 divides tp=4
    sh = specs(4)
    assert sh.moments.m2.spec == P("data", "model", None, None, None)
    assert sh.moments.g2.spec == P("data", "model", None, None)
    # feature mode: Hkv=2 doesn't divide; m-moments on Dv, g replicated
    sh = specs(2)
    assert sh.moments.m2.spec == P("data", None, None, None, "model")
    assert sh.moments.m0.spec == P("data", None, "model")
    assert sh.moments.g2.spec == P("data", None, None, None)
    # softmax KV cache: heads when divisible...
    sh = specs(4, family="softmax")
    assert sh.kv.k.spec == P("data", "model", None, None)
    # ...else the sequence dim — and NEVER head_dim
    sh = specs(2, family="softmax")
    assert sh.kv.k.spec == P("data", None, "model", None)
    assert sh.kv.mask.spec == P("data", None, "model")
