"""Checkpointing + fault-tolerance behaviour."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ft import StragglerMonitor, run_with_restarts


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "b": {"scale": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 100, t, extra={"note": "x"})
    restored, step, extra = load_checkpoint(str(tmp_path), t)
    assert step == 100 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomicity_partial_save_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed later save: tmp dir exists but LATEST not updated
    os.makedirs(tmp_path / ".tmp_step_00000002/arrays", exist_ok=True)
    _, step, _ = load_checkpoint(str(tmp_path), t)
    assert step == 1


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t, block=False)
    mgr.wait()
    tags = sorted(x for x in os.listdir(tmp_path) if x.startswith("step_"))
    assert tags == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved unsharded restores under a different 'mesh' (here:
    explicit device_put shardings on 1 device — the mesh-agnostic path)."""
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    from repro.launch.mesh import _make_mesh
    mesh = _make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), t)
    restored, step, _ = load_checkpoint(str(tmp_path), t, shardings=sh)
    assert step == 5
    assert all(x.sharding is not None for x in jax.tree.leaves(restored))


def test_run_with_restarts_recovers(tmp_path):
    """Injected worker failure: supervisor restores from ckpt and finishes."""
    mgr = CheckpointManager(str(tmp_path))
    calls = {"n": 0}

    def make_state():
        params = {"w": jnp.zeros((2,))}
        start = 0
        if mgr.latest_step() is not None:
            (params,), start, _ = mgr.restore((params,))
        return params, start

    def run(params, start):
        calls["n"] += 1
        for step in range(start, 10):
            params = {"w": params["w"] + 1.0}
            mgr.save(step + 1, (params,))
            if calls["n"] == 1 and step == 4:
                raise RuntimeError("node lost")
        return int(params["w"][0])

    total = run_with_restarts(make_state, run, max_restarts=3)
    assert total == 10          # 5 steps before crash + resumed 5..9
    assert calls["n"] == 2


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for _ in range(10):
        mon.start_step()
        mon._t0 -= 0.01          # simulate 10ms steps
        mon.end_step()
    assert not mon.straggling
    for _ in range(2):
        mon.start_step()
        mon._t0 -= 0.1           # 100ms — 10x median
        mon.end_step()
    assert mon.straggling
    assert mon.stats()["median_s"] > 0
