"""Core fastmax: every production path matches the O(N^2) oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (fastmax_attention, fastmax_decode_step,
                        fastmax_prefill, compute_moments)
from repro.core.ref import normalize_qk

jax.config.update("jax_enable_x64", True)


def mk(rng, b, hq, hkv, n, d, dv, dtype=jnp.float64):
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


@pytest.mark.parametrize("p", [1, 2])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("impl", ["chunked", "rowwise"])
@pytest.mark.parametrize("shape", [(1, 2, 1, 33, 4, 4), (2, 4, 2, 67, 8, 8),
                                   (1, 8, 8, 40, 16, 16)])
def test_matches_oracle(p, causal, impl, shape):
    rng = np.random.default_rng(hash((p, causal, impl)) % 2**31)
    q, k, v = mk(rng, *shape)
    ref = fastmax_attention(q, k, v, p=p, causal=causal, impl="oracle")
    out = fastmax_attention(q, k, v, p=p, causal=causal, impl=impl,
                            chunk_size=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("p", [1, 2])
def test_custom_vjp_matches_autodiff(p):
    rng = np.random.default_rng(3)
    q, k, v = mk(rng, 1, 4, 2, 45, 8, 8)

    def loss(custom):
        def f(q, k, v):
            o = fastmax_attention(q, k, v, p=p, causal=True, impl="chunked",
                                  chunk_size=16, custom_grad=custom)
            return jnp.sum(jnp.sin(o))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g_custom = loss(True)
    g_plain = loss(False)
    for a, b in zip(g_custom, g_plain):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-9)


def test_custom_vjp_fewer_residual_bytes():
    """Paper §2.5: memory-reduced backward. The reversible-scan VJP must not
    store the per-chunk O(N/c * D^2 Dv) moment carries that plain autodiff
    saves."""
    rng = np.random.default_rng(4)
    q, k, v = mk(rng, 1, 2, 2, 256, 16, 16, dtype=jnp.float32)

    def residual_bytes(custom):
        def f(q, k, v):
            o = fastmax_attention(q, k, v, p=2, causal=True, impl="chunked",
                                  chunk_size=16, custom_grad=custom)
            return jnp.sum(o)
        # linearize stores the residuals
        _, f_vjp = jax.vjp(f, q, k, v)
        leaves = jax.tree_util.tree_leaves(f_vjp)
        return sum(x.size * x.dtype.itemsize for x in leaves
                   if hasattr(x, "size"))

    assert residual_bytes(True) < 0.2 * residual_bytes(False)


def test_decode_stream_equals_full():
    rng = np.random.default_rng(5)
    q, k, v = mk(rng, 2, 4, 2, 33, 8, 8)
    full = fastmax_attention(q, k, v, p=2, causal=True, impl="oracle")
    o_pre, state = fastmax_prefill(q[:, :, :20], k[:, :, :20], v[:, :, :20],
                                   p=2, chunk_size=8)
    np.testing.assert_allclose(np.asarray(o_pre), np.asarray(full[:, :, :20]),
                               rtol=1e-8, atol=1e-8)
    for t in range(20, 33):
        o_t, state = fastmax_decode_step(
            state, q[:, :, t:t + 1], k[:, :, t:t + 1], v[:, :, t:t + 1], p=2)
        np.testing.assert_allclose(np.asarray(o_t[:, :, 0]),
                                   np.asarray(full[:, :, t]),
                                   rtol=1e-7, atol=1e-8)


def test_kv_mask_removes_tokens_exactly():
    """A masked key must contribute nothing (numerator AND denominator)."""
    rng = np.random.default_rng(6)
    q, k, v = mk(rng, 1, 2, 2, 24, 8, 8)
    keep = 17
    mask = jnp.concatenate([jnp.ones((1, 2, keep)), jnp.zeros((1, 2, 7))],
                           axis=-1)
    masked = fastmax_attention(q, k, v, p=2, causal=False, impl="chunked",
                               kv_mask=mask, chunk_size=8)
    trunc = fastmax_attention(q, k[:, :, :keep], v[:, :, :keep], p=2,
                              causal=False, impl="chunked", chunk_size=8)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(trunc),
                               rtol=1e-8, atol=1e-8)


def test_moments_additivity():
    rng = np.random.default_rng(7)
    _, k, v = mk(rng, 1, 2, 2, 40, 8, 8)
    kh = normalize_qk(k)
    full = compute_moments(kh, v, p=2)
    a = compute_moments(kh[:, :, :15], v[:, :, :15], p=2)
    b = compute_moments(kh[:, :, 15:], v[:, :, 15:], p=2)
    for x, y in zip(full, a + b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-9, atol=1e-9)


def test_dropout_variants_run_and_differ():
    rng = np.random.default_rng(8)
    q, k, v = mk(rng, 1, 2, 2, 32, 8, 8, dtype=jnp.float32)
    keyr = jax.random.PRNGKey(0)
    outs = {}
    for mode in ("quadratic", "1d"):
        outs[mode] = fastmax_attention(
            q, k, v, p=2, causal=True, impl="rowwise", dropout_rate=0.3,
            dropout_mode=mode, dropout_rng=keyr)
        assert bool(jnp.all(jnp.isfinite(outs[mode])))
    base = fastmax_attention(q, k, v, p=2, causal=True, impl="rowwise")
    assert float(jnp.max(jnp.abs(outs["quadratic"] - base))) > 1e-6
    assert float(jnp.max(jnp.abs(outs["1d"] - base))) > 1e-6
