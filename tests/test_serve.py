"""Continuous-batching engine tier (`serve` marker; `make test-serve`).

The load-bearing contract: the engine — staggered admissions, chunked
prefill mixed with batched decode, slot reuse — produces EXACTLY the
tokens `launch.serve.generate` produces per request, for every registered
decode-capable backend (softmax KV, fastmax p in {1,2} chunked, fastmax
kernel routing), on a GQA config, plus the SSM-mixer architectures.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import init_decode_state, init_model
from repro.serve import PrefixCache, Request, Scheduler, ServeEngine
from repro.serve.slots import SlotManager

pytestmark = pytest.mark.serve

DECODE_SPECS = ["softmax", "fastmax1-chunked", "fastmax2-chunked",
                "fastmax2-kernel"]


def _setup(spec_name=None, arch="qwen3-1.7b", seed=0):
    cfg = get_smoke_config(arch)
    if spec_name is not None:
        cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(spec_name))
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _ref(params, cfg, prompt, gen, max_len, eos_id=None):
    return np.asarray(generate(params, cfg, jnp.asarray(prompt[None]), gen,
                               max_len=max_len, eos_id=eos_id))[0]


# ---------------------------------------------------------------------------
# slot pool unit behavior
# ---------------------------------------------------------------------------


def test_slot_write_read_roundtrip():
    cfg, _ = _setup("fastmax2-chunked")
    sm = SlotManager(cfg, max_slots=3, max_len=32)
    # perturb slot 1 with a recognisable unit state, read it back
    unit = jax.tree.map(lambda l: jnp.full_like(l, 7), sm.fresh_unit)
    sm.admit(1, unit_state=unit)
    got = sm.snapshot(1)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(unit)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # neighbours untouched (still the fresh init, not 7s)
    other = sm.snapshot(0)
    for a, b in zip(jax.tree.leaves(other), jax.tree.leaves(sm.fresh_unit)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slot_axes_cover_every_leaf():
    # every decode-state leaf must expose a batch/slot axis — softmax KV,
    # moments, and both SSM families
    for arch in ["qwen3-1.7b", "xlstm-1.3b", "jamba-v0.1-52b"]:
        cfg, _ = _setup(arch=arch)
        sm = SlotManager(cfg, max_slots=2, max_len=32)
        n_state = len(jax.tree.leaves(sm.state))
        assert n_state == len(jax.tree.leaves(sm.axes))


def test_slot_memory_constant_for_fastmax():
    from repro.core.decode_state import decode_state_bytes
    cfg_f, _ = _setup("fastmax2-chunked")
    cfg_s, _ = _setup("softmax")
    f_small = decode_state_bytes(cfg_f, 1, 128)
    f_big = decode_state_bytes(cfg_f, 1, 8192)
    s_small = decode_state_bytes(cfg_s, 1, 128)
    s_big = decode_state_bytes(cfg_s, 1, 8192)
    assert f_small == f_big          # O(1) in context
    assert s_big > s_small * 32      # KV cache is linear in context


# ---------------------------------------------------------------------------
# engine vs generate(): token parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", DECODE_SPECS)
def test_engine_parity_staggered(spec):
    """Staggered admissions + ragged prompts produce the same tokens as
    per-request generate() for every decode-capable backend (GQA config)."""
    cfg, params = _setup(spec)
    assert cfg.n_kv_heads < cfg.n_heads  # GQA is actually exercised
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)  # ragged tail
    p1 = rng.integers(0, cfg.vocab_size, 23).astype(np.int32)
    G = 6
    ref0 = _ref(params, cfg, p0, G, 64)
    ref1 = _ref(params, cfg, p1, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    r0 = eng.submit(p0, G)
    outs = {}
    for _ in range(3):                      # p1 arrives mid-flight
        for f in eng.step():
            outs[f.rid] = f.tokens
    r1 = eng.submit(p1, G)
    outs.update(eng.run())
    np.testing.assert_array_equal(outs[r0], ref0)
    np.testing.assert_array_equal(outs[r1], ref1)


@pytest.mark.slow  # ~2 min combined: whole-model SSM prefill compiles
@pytest.mark.parametrize("arch", ["xlstm-1.3b", "jamba-v0.1-52b"])
def test_engine_parity_ssm_mixers(arch):
    """SSM-mixer archs resume via recurrent state (exact-length ragged
    chunks, no kv_mask) and must still match generate()."""
    cfg, params = _setup(arch=arch)
    rng = np.random.default_rng(2)
    p0 = rng.integers(0, cfg.vocab_size, 33).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
    G = 5
    ref0 = _ref(params, cfg, p0, G, 64)
    ref1 = _ref(params, cfg, p1, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    r0 = eng.submit(p0, G)
    outs = {}
    for _ in range(2):
        for f in eng.step():
            outs[f.rid] = f.tokens
    r1 = eng.submit(p1, G)
    outs.update(eng.run())
    np.testing.assert_array_equal(outs[r0], ref0)
    np.testing.assert_array_equal(outs[r1], ref1)


def test_engine_slot_reuse_single_slot():
    """max_slots=1 serving 3 queued requests: each admit fully overwrites
    the evicted slot — no state leaks between tenants."""
    cfg, params = _setup("fastmax2-chunked")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (19, 40, 8)]
    G = 4
    refs = [_ref(params, cfg, p, G, 64) for p in prompts]
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    rids = [eng.submit(p, G) for p in prompts]
    outs = eng.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)


def test_prefill_round_robin_interleaves():
    """Two equal prompts admitted together must make chunk-for-chunk
    progress (round-robin), not slot-0-to-completion-first (head-of-line
    bias that inflates slot 1's TTFT) — and parity must survive the
    interleaving."""
    cfg, params = _setup("fastmax2-chunked")
    rng = np.random.default_rng(7)
    p0 = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    G = 4
    ref0 = _ref(params, cfg, p0, G, 64)
    ref1 = _ref(params, cfg, p1, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64, chunk=8)
    r0 = eng.submit(p0, G)
    r1 = eng.submit(p1, G)
    eng.step()
    eng.step()
    # after two single-chunk ticks BOTH prompts have advanced; the biased
    # lowest-slot-first scan would leave slot 1 still at position 0
    pos = np.asarray(eng.slots.position)
    assert pos[0] > 0 and pos[1] > 0, pos
    outs = eng.run()
    np.testing.assert_array_equal(outs[r0], ref0)
    np.testing.assert_array_equal(outs[r1], ref1)


def test_submit_rejects_empty_prompt_and_zero_gen():
    cfg, params = _setup("fastmax2-chunked")
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(0, np.int32), 4)
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        eng.submit(np.arange(4, dtype=np.int32), 0)
    assert eng.pending == 0            # nothing was enqueued


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------


def test_prefix_cache_hit_matches_cold_path():
    """A request resumed from a cached prefix snapshot must decode the
    exact cold-path tokens, stepped out to 64 tokens."""
    cfg, params = _setup("fastmax2-chunked")
    C = cfg.chunk_size
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab_size, 2 * C).astype(np.int32)
    a = np.concatenate([shared,
                        rng.integers(0, cfg.vocab_size, 5).astype(np.int32)])
    b = np.concatenate([shared,
                        rng.integers(0, cfg.vocab_size, 9).astype(np.int32)])
    G = 64
    max_len = len(b) + G
    ref_b = _ref(params, cfg, b, G, max_len)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=max_len,
                      prefix_cache_bytes=1 << 30)
    eng.submit(a, G)
    eng.run()                                  # populates the cache
    rb = eng.submit(b, G)
    outs = eng.run()
    assert eng.prefix_cache.hits >= 1          # b resumed from a's prefix
    np.testing.assert_array_equal(outs[rb], ref_b)


def test_prefix_cache_lru_byte_budget():
    cache = PrefixCache(byte_budget=100, chunk=4)
    state1 = {"x": np.zeros(10, np.float32)}   # 40 bytes
    p1 = np.arange(8, dtype=np.int32)
    p2 = np.arange(100, 108, dtype=np.int32)
    p3 = np.arange(200, 208, dtype=np.int32)
    cache.insert(p1, 4, state1)
    cache.insert(p2, 4, state1)
    assert cache.bytes == 80 and len(cache) == 2
    cache.insert(p3, 4, state1)                # 120 > 100: evicts oldest
    assert cache.bytes == 80 and len(cache) == 2
    assert cache.lookup(p1)[1] is None         # p1 was LRU-evicted
    assert cache.lookup(p3)[1] is not None
    # oversized entries are refused outright
    cache.insert(np.arange(300, 308, dtype=np.int32), 4,
                 {"x": np.zeros(100, np.float32)})
    assert cache.bytes == 80


def test_prefix_cache_stats_transitions():
    """hits/misses/insertions/evictions move exactly when they should; in
    particular a prompt too short to HAVE a cacheable prefix (< one chunk
    past the boundary) is not a miss."""
    cache = PrefixCache(byte_budget=100, chunk=4)
    state = {"x": np.zeros(10, np.float32)}    # 40 bytes

    # sub-chunk prompt: no key of length k*chunk < plen exists -> no miss
    assert cache.lookup(np.arange(3, dtype=np.int32)) == (0, None)
    assert cache.lookup(np.arange(4, dtype=np.int32)) == (0, None)
    assert cache.stats()["misses"] == 0

    # long enough to have a prefix, but cache is cold -> a real miss
    p = np.arange(8, dtype=np.int32)
    assert cache.lookup(p) == (0, None)
    assert cache.stats()["misses"] == 1

    cache.insert(p, 4, state)
    assert cache.stats()["insertions"] == 1
    m, snap = cache.lookup(p)                  # now a hit at m=4
    assert m == 4 and snap is state
    assert cache.stats() == {"entries": 1, "bytes": 40, "hits": 1,
                             "misses": 1, "insertions": 1, "evictions": 0}

    # two more 40-byte entries blow the 100-byte budget -> one eviction
    cache.insert(np.arange(100, 108, dtype=np.int32), 4, state)
    cache.insert(np.arange(200, 208, dtype=np.int32), 4, state)
    st = cache.stats()
    assert st["insertions"] == 3 and st["evictions"] == 1
    assert st["entries"] == 2 and st["bytes"] == 80


def test_prefix_cache_resume_is_strictly_shorter():
    cache = PrefixCache(byte_budget=1 << 20, chunk=4)
    p = np.arange(8, dtype=np.int32)
    cache.insert(p, 8, {"x": np.zeros(2, np.float32)})
    # a full-prompt snapshot must NOT be returned for the same prompt —
    # at least one token has to run prefill to produce the first logits
    m, state = cache.lookup(p)
    assert (m, state) == (0, None)


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------


def _req(rid, plen, tick=0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=1, submit_tick=tick)


def test_scheduler_fcfs_order():
    s = Scheduler("fcfs")
    for r in [_req(0, 5), _req(1, 50), _req(2, 10)]:
        s.push(r)
    assert [s.pop(0).rid for _ in range(3)] == [0, 1, 2]


def test_scheduler_lpf_prefers_long_prompts():
    s = Scheduler("lpf", max_wait=100)
    for r in [_req(0, 5), _req(1, 50), _req(2, 10)]:
        s.push(r)
    assert [s.pop(0).rid for _ in range(3)] == [1, 2, 0]


def test_scheduler_lpf_starvation_guard():
    s = Scheduler("lpf", max_wait=10)
    s.push(_req(0, 5, tick=0))        # short, would lose every lpf round
    s.push(_req(1, 50, tick=9))
    s.push(_req(2, 60, tick=9))
    assert s.pop(9).rid == 2          # lpf still winning
    assert s.pop(10).rid == 0         # rid 0 has starved past max_wait
    assert s.pop(11).rid == 1


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError):
        Scheduler("priority")


# ---------------------------------------------------------------------------
# eos early stop
# ---------------------------------------------------------------------------


def _emitted_token(params, cfg, prompt):
    """A token the model actually emits (so eos fires mid-generation)."""
    toks = _ref(params, cfg, prompt, 4, 64)
    return int(toks[1])


def test_generate_eos_early_stop():
    cfg, params = _setup("fastmax2-chunked")
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    eos = _emitted_token(params, cfg, prompt)
    G = 8
    free = _ref(params, cfg, prompt, G, 64)
    stopped = _ref(params, cfg, prompt, G, 64, eos_id=eos)
    k = int(np.argmax(free == eos))            # first eos position
    np.testing.assert_array_equal(stopped[:k + 1], free[:k + 1])
    assert (stopped[k:] == eos).all()          # frozen after eos


def test_engine_eos_early_stop():
    cfg, params = _setup("fastmax2-chunked")
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    eos = _emitted_token(params, cfg, prompt)
    G = 8
    free = _ref(params, cfg, prompt, G, 64)
    k = int(np.argmax(free == eos))
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, eos_id=eos)
    rid = eng.submit(prompt, G)
    outs = eng.run()
    np.testing.assert_array_equal(outs[rid], free[:k + 1])  # ends at eos


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_stream_yields_tokens_in_order():
    cfg, params = _setup("fastmax2-chunked")
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    G = 5
    ref = _ref(params, cfg, prompt, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    got = list(eng.stream(prompt, G))
    np.testing.assert_array_equal(np.asarray(got, np.int32), ref)
