"""Context-parallel (seq-mode) tier (`cp` marker; `make test-cp`).

The load-bearing contract: training attention sharded over a "seq" mesh
axis — each device running the Pallas chunk scan on its contiguous token
shard, seeded by ONE exclusive-prefix exchange of the constant-size moment
carry, backward closed by the mirrored suffix exchange — produces the
EXACT outputs and gradients of the single-device kernel, for both exchange
implementations (ring / allgather), GQA included, p in {1, 2}.

Multi-device cases need 8 host devices (REPRO_TEST_DEVICES=8, injected by
conftest). The plan-selection and byte-model tests are host-only.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.kernels.sharded import (cp_boundary_model, cp_carry_bytes,
                                   fastmax_sharded, pick_cp_exchange,
                                   plan_kernel_sharding)

pytestmark = pytest.mark.cp


def mk(rng, b, hq, hkv, n, d, dv, dtype=jnp.float64):
    q = jnp.asarray(rng.standard_normal((b, hq, n, d)) / np.sqrt(d), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, n, d)) / np.sqrt(d), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, n, dv)), dtype)
    return q, k, v


def _seq_mesh(cp, n_dev=8):
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh((n_dev // cp, cp), ("data", "seq"))


def _plan(mesh, q, k, v):
    return plan_kernel_sharding(mesh, batch=q.shape[0], hq=q.shape[1],
                                hkv=k.shape[1], dv=v.shape[-1],
                                seq_len=q.shape[2])


def _oracle_grads(q, k, v, do, p, cs):
    from repro.kernels import ops as kernel_ops

    def f(q, k, v):
        return kernel_ops.fastmax(q, k, v, p=p, causal=True, chunk_size=cs,
                                  denom_eps=1e-6)

    o, vjp_fn = jax.vjp(f, q, k, v)
    return (o,) + vjp_fn(do)


def _cp_grads(q, k, v, do, p, cs, plan):
    def f(q, k, v):
        return fastmax_sharded(q, k, v, p=p, causal=True, chunk_size=cs,
                               denom_eps=1e-6, plan=plan)

    o, vjp_fn = jax.vjp(f, q, k, v)
    return (o,) + vjp_fn(do)


# ---------------------------------------------------------------------------
# exact parity vs the single-device kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cp", [2, 4])
@pytest.mark.parametrize("p", [1, 2])
def test_cp_grads_match_single_device_f64(shard_devices, monkeypatch, cp, p):
    """CP=2/4 training fwd+bwd vs the single-device chunked-scan kernel,
    f64 tight, GQA (Hq=4, Hkv=2), both exchange impls."""
    rng = np.random.default_rng(10 + cp + 10 * p)
    b, hq, hkv, n, d, dv, cs = 2, 4, 2, 128, 8, 8, 16
    q, k, v = mk(rng, b, hq, hkv, n, d, dv)
    do = jnp.asarray(rng.standard_normal((b, hq, n, dv)), jnp.float64)
    ref = _oracle_grads(q, k, v, do, p, cs)

    mesh = _seq_mesh(cp)
    plan = _plan(mesh, q, k, v)
    assert plan is not None and plan.mode == "seq" and plan.cp == cp
    for impl in ("allgather", "ring"):
        monkeypatch.setenv("REPRO_CP_EXCHANGE", impl)
        got = _cp_grads(q, k, v, do, p, cs, plan)
        for name, r, g in zip(("o", "dq", "dk", "dv"), ref, got):
            err = float(jnp.max(jnp.abs(r - g)))
            assert err < 1e-10, f"{name} impl={impl}: {err}"


def test_cp_ring_matches_allgather(shard_devices, monkeypatch):
    """The two exchange impls differ only in summation order: allclose,
    and both within low-precision tolerance of the f32 oracle."""
    rng = np.random.default_rng(3)
    b, hq, hkv, n, d, dv, cs = 1, 2, 1, 64, 4, 8, 16
    q, k, v = mk(rng, b, hq, hkv, n, d, dv, jnp.float32)
    do = jnp.asarray(rng.standard_normal((b, hq, n, dv)), jnp.float32)
    ref = _oracle_grads(q, k, v, do, 2, cs)

    mesh = _seq_mesh(2)
    plan = _plan(mesh, q, k, v)
    outs = {}
    for impl in ("allgather", "ring"):
        monkeypatch.setenv("REPRO_CP_EXCHANGE", impl)
        outs[impl] = _cp_grads(q, k, v, do, 2, cs, plan)
    for name, a, r, f in zip(("o", "dq", "dk", "dv"),
                             outs["allgather"], outs["ring"], ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_cp_bf16_tolerance(shard_devices, monkeypatch):
    """bf16 inputs stay within bf16-scale error of the f32 oracle under
    CP (the carries accumulate in f32 inside the kernels)."""
    rng = np.random.default_rng(4)
    b, hq, hkv, n, d, dv, cs = 1, 2, 2, 64, 4, 4, 16
    qf, kf, vf = mk(rng, b, hq, hkv, n, d, dv, jnp.float32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (qf, kf, vf))
    dof = jnp.asarray(rng.standard_normal((b, hq, n, dv)), jnp.float32)
    do = dof.astype(jnp.bfloat16)
    ref = _oracle_grads(qf, kf, vf, dof, 2, cs)

    mesh = _seq_mesh(2)
    plan = _plan(mesh, q, k, v)
    monkeypatch.setenv("REPRO_CP_EXCHANGE", "ring")
    got = _cp_grads(q, k, v, do, 2, cs, plan)
    for name, r, g in zip(("o", "dq", "dk", "dv"), ref, got):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r), rtol=0.1, atol=0.1,
                                   err_msg=name)


# ---------------------------------------------------------------------------
# plan selection
# ---------------------------------------------------------------------------


def test_plan_seq_mode_selection(shard_devices):
    mesh = _seq_mesh(4)
    # training-shaped call (seq_len passed, divisible) -> seq mode
    plan = plan_kernel_sharding(mesh, batch=2, hq=4, hkv=2, dv=8,
                                seq_len=128)
    assert plan.mode == "seq" and plan.cp == 4 and plan.tp == 1
    assert "shard_map[seq]" in plan.describe()
    # no seq_len (decode/prefill callers) -> degenerate heads wrap
    plan = plan_kernel_sharding(mesh, batch=2, hq=4, hkv=2, dv=8)
    assert plan.mode == "heads" and plan.cp == 1
    # indivisible sequence -> no seq mode either
    plan = plan_kernel_sharding(mesh, batch=2, hq=4, hkv=2, dv=8,
                                seq_len=130)
    assert plan.mode == "heads"


def test_plan_tp_wins_over_cp(shard_devices):
    """CP×TP composition is deferred: with a 'model' axis > 1 the
    head/feature modes win and the seq axis is left replicated-unused."""
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((2, 2, 2), ("data", "model", "seq"))
    plan = plan_kernel_sharding(mesh, batch=2, hq=4, hkv=2, dv=8,
                                seq_len=128)
    assert plan.mode == "heads" and plan.tp == 2 and plan.cp == 1
    plan = plan_kernel_sharding(mesh, batch=2, hq=3, hkv=1, dv=8,
                                seq_len=128)
    assert plan.mode == "feature"


# ---------------------------------------------------------------------------
# boundary-bytes model (host-only)
# ---------------------------------------------------------------------------


def test_cp_carry_bytes_independent_of_n():
    kw = dict(b=4, hkv=8, d=64, dv=64, p=2)
    m_small = cp_boundary_model(n=4096, cp=8, **kw)
    m_big = cp_boundary_model(n=1048576, cp=8, **kw)
    # the moment-carry payload is O(D^2 Dv): constant in N
    assert (m_small["carry_bytes_per_boundary"]
            == m_big["carry_bytes_per_boundary"]
            == cp_carry_bytes(itemsize=4, **kw))
    # the ring-attention alternative rotates O(N/cp) KV rows: grows with N
    assert (m_big["ring_attention_bytes_per_boundary"]
            == 256 * m_small["ring_attention_bytes_per_boundary"])
    # p=1 drops the dominant m2/g2 (D^2-scale) terms entirely
    assert cp_carry_bytes(b=4, hkv=8, d=64, dv=64, p=1) * 10 \
        < cp_carry_bytes(b=4, hkv=8, d=64, dv=64, p=2)


def test_pick_cp_exchange_budget_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_CP_EXCHANGE", raising=False)
    assert pick_cp_exchange(4, 1 << 20) == "allgather"   # 4 MB gathered
    assert pick_cp_exchange(4, 1 << 30) == "ring"        # 4 GB gathered
    monkeypatch.setenv("REPRO_CP_EXCHANGE", "ring")
    assert pick_cp_exchange(4, 1 << 20) == "ring"
    monkeypatch.setenv("REPRO_CP_EXCHANGE", "allgather")
    assert pick_cp_exchange(4, 1 << 30) == "allgather"
