"""Schedule autotuner: forced-schedule parity, cache behavior, env modes.

The load-bearing property: a schedule changes WHERE work happens (block
shapes, chunking, grid semantics), never WHAT is computed — so every
candidate schedule the tuner can emit must produce the same outputs and
gradients as the untuned default, for all four kernels. f64 runs pin that
to ~1e-12 (summation-order-level); f32 gets a looser tol. On top of that:
cache round-trip + determinism, and the REPRO_AUTOTUNE=0 escape hatch
being byte-identical to calling the kernels with no autotuner at all.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_fastmax_state
from repro.core.ref import normalize_qk
from repro.kernels import autotune, ops
from repro.kernels.autotune import (CACHE_VERSION, Schedule, ShapeKey,
                                    build_gate_entries, candidate_schedules,
                                    cost_model, default_schedule, key_str,
                                    load_cache, lookup_schedule, save_cache,
                                    tune)
from repro.kernels.fastmax_causal import fastmax_causal_pallas
from repro.kernels.fastmax_causal_bwd import fastmax_causal_bwd_pallas
from repro.kernels.fastmax_decode import fastmax_decode_pallas
from repro.kernels.fastmax_noncausal import fastmax_noncausal_pallas
from repro.kernels.tiling import divisors, pick_blk, pick_bm

jax.config.update("jax_enable_x64", True)

pytestmark = pytest.mark.kernels


def mk(rng, b, hq, hkv, n, d, dv, dtype):
    q = normalize_qk(jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype))
    k = normalize_qk(jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype))
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


# parity shape: small enough that interpret-mode sweeps stay fast, with a
# non-divisor N (padding in play) and a nontrivial candidate space
B, HQ, HKV, N, D, DV = 1, 4, 2, 40, 4, 4
DTYPES = [(jnp.float64, 1e-12), (jnp.float32, 2e-5)]


def _key(kernel, dtype, n=N):
    return ShapeKey(kernel, n, D, DV, HQ // HKV, 2,
                    jnp.dtype(dtype).name, "cpu")


@pytest.fixture(autouse=True)
def _clean_lookup_state(monkeypatch, tmp_path):
    """Each test gets autotune OFF by default and a throwaway cache path
    (never the committed in-repo cache), with the provenance log reset."""
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    autotune.clear_lookups()
    yield
    autotune.clear_lookups()


# ---------------------------------------------------------------------------
# tiling pickers (satellite: divisor enumeration + budget validation)
# ---------------------------------------------------------------------------

def test_divisors_enumeration():
    assert divisors(1) == (1,)
    assert divisors(12) == (1, 2, 3, 4, 6, 12)
    assert divisors(128) == (1, 2, 4, 8, 16, 32, 64, 128)
    for bad in (0, -3, 2.5, "8"):
        with pytest.raises(ValueError):
            divisors(bad)


@pytest.mark.parametrize("d", [1, 4, 16, 64, 128, 96])
def test_pick_bm_matches_linear_scan(d):
    for budget in (1, 8, 512, 2048, 10**6):
        brute = max(bm for bm in range(1, d + 1)
                    if d % bm == 0 and bm * d <= budget) if any(
                        d % bm == 0 and bm * d <= budget
                        for bm in range(1, d + 1)) else 1
        assert pick_bm(d, budget) == max(brute, 1)


@pytest.mark.parametrize("d,dv", [(4, 4), (16, 16), (64, 64), (128, 128),
                                  (128, 8)])
def test_pick_blk_matches_linear_scan(d, dv):
    for budget in (1, d * d, 1 << 20, 2 << 20):
        feas = [blk for blk in range(1, dv + 1)
                if dv % blk == 0 and d * d * blk <= budget]
        assert pick_blk(d, dv, budget) == (max(feas) if feas else 1)


def test_pickers_validate_budget():
    for bad in (0, -1, 1.5, "512"):
        with pytest.raises(ValueError):
            pick_bm(8, bad)
        with pytest.raises(ValueError):
            pick_blk(8, 8, bad)
    with pytest.raises(ValueError):
        pick_blk(0, 8)


# ---------------------------------------------------------------------------
# candidate space sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", autotune.KERNELS)
def test_candidates_are_valid_and_contain_default(kernel):
    key = _key(kernel, jnp.float32, n=1 if kernel == "decode" else N)
    cands = candidate_schedules(kernel, key, 128)
    assert default_schedule(kernel, D, DV, 128) in cands
    assert len(cands) == len(set(cands))
    for s in cands:
        assert D % s.bm == 0
        assert DV % s.blk == 0
        assert s.chunk_size >= 1
        assert s.grid in ("parallel", "arbitrary")


def test_candidates_rejects_unknown_kernel():
    with pytest.raises(ValueError):
        candidate_schedules("flash", _key("causal_fwd", jnp.float32), 128)


def test_cost_model_flags_vmem_infeasible():
    # a 128x128 p=2 head with an unblocked bwd carry pair (2 * D^2 * Dv * 4
    # = 16 MB of scratch alone) cannot fit 16 MB of VMEM
    key = ShapeKey("causal_bwd", 1024, 128, 128, 4, 2, "float32", "cpu")
    bad = Schedule(bm=1, blk=128, chunk_size=128, grid="parallel")
    good = Schedule(bm=1, blk=pick_blk(128, 128, 1 << 20), chunk_size=128,
                    grid="parallel")
    assert math.isinf(cost_model(key, bad))
    assert math.isfinite(cost_model(key, good))


# ---------------------------------------------------------------------------
# forced-schedule parity: every candidate == default, all four kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_causal_fwd_schedule_parity(dtype, tol):
    rng = np.random.default_rng(0)
    q, k, v = mk(rng, B, HQ, HKV, N, D, DV, dtype)
    o0, st0 = fastmax_causal_pallas(q, k, v, p=2, interpret=True,
                                    return_state=True)
    for s in candidate_schedules("causal_fwd", _key("causal_fwd", dtype),
                                 128):
        o, st = fastmax_causal_pallas(
            q, k, v, p=2, interpret=True, return_state=True,
            chunk_size=s.chunk_size, bm=s.bm, blk=s.blk, grid=s.grid)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o0),
                                   rtol=tol, atol=tol, err_msg=str(s))
        for a, b in zip(st, st0):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=tol, atol=tol, err_msg=str(s))


@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_causal_bwd_schedule_parity(dtype, tol):
    rng = np.random.default_rng(1)
    q, k, v = mk(rng, B, HQ, HKV, N, D, DV, dtype)
    do = jnp.asarray(rng.normal(size=(B, HQ, N, DV)), dtype)
    _, st = fastmax_causal_pallas(q, k, v, p=2, interpret=True,
                                  return_state=True)
    g0 = fastmax_causal_bwd_pallas(q, k, v, st, do, p=2, interpret=True)
    for s in candidate_schedules("causal_bwd", _key("causal_bwd", dtype),
                                 128):
        g = fastmax_causal_bwd_pallas(
            q, k, v, st, do, p=2, interpret=True,
            chunk_size=s.chunk_size, bm=s.bm, blk=s.blk, grid=s.grid)
        for a, b, name in zip(g, g0, ("dq", "dk", "dv")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=tol, atol=tol,
                                       err_msg=f"{name} {s}")


@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_decode_schedule_parity(dtype, tol):
    rng = np.random.default_rng(2)
    q, k, v = mk(rng, B, HQ, HKV, 1, D, DV, dtype)
    st = tuple(init_fastmax_state(B, HKV, D, DV, p=2, dtype=dtype))
    o0, ns0 = fastmax_decode_pallas(q, k, v, st, p=2, interpret=True)
    for s in candidate_schedules("decode", _key("decode", dtype, n=1), 128):
        o, ns = fastmax_decode_pallas(q, k, v, st, p=2, interpret=True,
                                      bm=s.bm, grid=s.grid)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o0),
                                   rtol=tol, atol=tol, err_msg=str(s))
        for a, b in zip(ns, ns0):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=tol, atol=tol, err_msg=str(s))


@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_noncausal_schedule_parity(dtype, tol):
    rng = np.random.default_rng(3)
    q, k, v = mk(rng, B, HQ, HKV, N, D, DV, dtype)
    o0 = fastmax_noncausal_pallas(q, k, v, p=2, interpret=True)
    for s in candidate_schedules("noncausal", _key("noncausal", dtype), 128):
        o = fastmax_noncausal_pallas(q, k, v, p=2, interpret=True,
                                     chunk_size=s.chunk_size, bm=s.bm,
                                     grid=s.grid)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o0),
                                   rtol=tol, atol=tol, err_msg=str(s))


def test_chunk_size_variation_parity():
    """Chunking differs across these (N=100 splits as 4x32 / 1x100-pad),
    so this is the one place cross-chunk summation order actually moves."""
    rng = np.random.default_rng(4)
    q, k, v = mk(rng, 1, 4, 2, 100, 8, 8, jnp.float64)
    o0 = fastmax_causal_pallas(q, k, v, p=2, interpret=True, chunk_size=128)
    for cs in (16, 32, 64):
        o = fastmax_causal_pallas(q, k, v, p=2, interpret=True,
                                  chunk_size=cs)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o0),
                                   rtol=1e-12, atol=1e-12)


def test_grads_through_forced_schedule():
    """ops.fastmax(schedule=...) differentiates: the custom_vjp threads the
    forced schedule through both the fwd and bwd kernels."""
    rng = np.random.default_rng(5)
    q, k, v = mk(rng, B, HQ, HKV, N, D, DV, jnp.float64)

    def loss(q, k, v, schedule=None):
        return jnp.sum(ops.fastmax(q, k, v, p=2, causal=True,
                                   interpret=True, schedule=schedule) ** 2)

    g0 = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    forced = Schedule(bm=2, blk=2, chunk_size=16, grid="arbitrary")
    g1 = jax.grad(lambda *a: loss(*a, schedule=forced))(q, k, v)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# env modes + byte-identity of the escape hatch
# ---------------------------------------------------------------------------

def test_mode_off_is_byte_identical(monkeypatch):
    rng = np.random.default_rng(6)
    q, k, v = mk(rng, B, HQ, HKV, N, D, DV, jnp.float32)
    base = fastmax_causal_pallas(q, k, v, p=2, interpret=True)

    for env in (None, "0"):
        if env is None:
            monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
        else:
            monkeypatch.setenv("REPRO_AUTOTUNE", env)
        out = ops.fastmax(q, k, v, p=2, causal=True, interpret=True)
        assert np.asarray(out).tobytes() == np.asarray(base).tobytes()

    # off-mode lookups return None but still record provenance
    assert lookup_schedule("causal_fwd", n=N, d=D, dv=DV, g=2, p=2,
                           dtype=jnp.float32, chunk_size=128) is None
    recs = autotune.snapshot_lookups()
    assert recs and recs[-1]["cache"] == "off"
    assert recs[-1]["source"] == "default"


def test_mode_validation():
    import os
    os.environ["REPRO_AUTOTUNE"] = "banana"
    try:
        with pytest.raises(ValueError):
            autotune.autotune_mode()
    finally:
        del os.environ["REPRO_AUTOTUNE"]


def test_offline_mode_uses_cache_then_cost_model(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE", "offline")
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))

    # miss -> deterministic cost-model winner, nothing written (offline
    # never persists)
    s1 = lookup_schedule("causal_fwd", n=N, d=D, dv=DV, g=2, p=2,
                         dtype=jnp.float32, chunk_size=128)
    assert isinstance(s1, Schedule)
    assert autotune.snapshot_lookups()[-1]["cache"] == "miss"
    assert not path.exists()

    # a planted cache entry wins over the cost model
    planted = Schedule(bm=1, blk=DV, chunk_size=64, grid="arbitrary")
    key = _key("causal_fwd", jnp.float32)
    save_cache(str(path), {key_str(key): {
        "schedule": dict(planted._asdict()), "source": "measured"}})
    autotune.clear_lookups()
    s2 = lookup_schedule("causal_fwd", n=N, d=D, dv=DV, g=2, p=2,
                         dtype=jnp.float32, chunk_size=128)
    assert s2 == planted
    rec = autotune.snapshot_lookups()[-1]
    assert rec["cache"] == "hit" and rec["source"] == "measured"


def test_stale_cache_entry_treated_as_miss(monkeypatch, tmp_path):
    """An entry whose blocks no longer divide the dims (code/schema drift)
    must not crash the kernels — it falls back to a fresh tune."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "offline")
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    key = _key("causal_fwd", jnp.float32)
    save_cache(str(path), {key_str(key): {
        "schedule": {"bm": 3, "blk": 3, "chunk_size": 128,
                     "grid": "parallel"}, "source": "measured"}})
    s = lookup_schedule("causal_fwd", n=N, d=D, dv=DV, g=2, p=2,
                        dtype=jnp.float32, chunk_size=128)
    assert isinstance(s, Schedule) and D % s.bm == 0 and DV % s.blk == 0
    assert autotune.snapshot_lookups()[-1]["cache"] == "miss"


def test_on_mode_persists_only_to_explicit_cache(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    path = tmp_path / "mine.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    s = lookup_schedule("decode", n=1, d=D, dv=DV, g=2, p=2,
                        dtype=jnp.float32, chunk_size=128)
    assert isinstance(s, Schedule)
    entries = load_cache(str(path))
    key = key_str(_key("decode", jnp.float32, n=1))
    assert entries[key]["schedule"] == dict(s._asdict())
    # and a rerun is a hit
    autotune.clear_lookups()
    assert lookup_schedule("decode", n=1, d=D, dv=DV, g=2, p=2,
                           dtype=jnp.float32, chunk_size=128) == s
    assert autotune.snapshot_lookups()[-1]["cache"] == "hit"


# ---------------------------------------------------------------------------
# cache round-trip + determinism + committed-cache freshness
# ---------------------------------------------------------------------------

def test_cache_round_trip(tmp_path):
    path = tmp_path / "rt.json"
    entries = {"k1": {"schedule": {"bm": 2, "blk": 4, "chunk_size": 128,
                                   "grid": "parallel"},
                      "source": "cost_model", "score": 1e-6}}
    save_cache(str(path), entries)
    assert load_cache(str(path)) == entries
    raw = json.loads(path.read_text())
    assert raw["version"] == CACHE_VERSION

    # version drift -> ignored wholesale
    raw["version"] = CACHE_VERSION + 1
    path.write_text(json.dumps(raw))
    assert load_cache(str(path)) == {}


def test_tune_is_deterministic():
    key = _key("causal_fwd", jnp.float32)
    r1 = tune(key, 128, allow_measure=False)
    r2 = tune(key, 128, allow_measure=False)
    assert r1 == r2
    assert r1[1] == "cost_model"


def test_gate_entries_deterministic_and_match_committed():
    e1 = build_gate_entries()
    e2 = build_gate_entries()
    assert e1 == e2
    # the committed cache (shipped for the dryrun-gate + bench shapes) must
    # agree with a fresh sweep — the same check CI's autotune job runs
    committed = load_cache(autotune.DEFAULT_CACHE)
    assert committed, "committed autotune_cache.json missing or unreadable"
    for ks, entry in e1.items():
        assert committed[ks]["schedule"] == entry["schedule"], ks
