"""Property-based tests (hypothesis) for the paper's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (requirements-dev.txt); skip, don't "
           "abort collection, when absent")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fastmax_attention
from repro.core.ref import (fastmax_attention_matrix_ref, normalize_qk,
                            poly_kernel)

jax.config.update("jax_enable_x64", True)

_shapes = st.tuples(
    st.integers(1, 2),            # B
    st.sampled_from([1, 2, 4]),   # H
    st.integers(3, 24),           # N
    st.sampled_from([2, 4, 8]),   # D
)


def _qkv(seed, b, h, n, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, n, d)))
    k = jnp.asarray(rng.normal(size=(b, h, n, d)))
    v = jnp.asarray(rng.normal(size=(b, h, n, d)))
    return q, k, v


@settings(max_examples=25, deadline=None)
@given(shape=_shapes, seed=st.integers(0, 2**20), causal=st.booleans())
def test_rows_sum_to_one_and_nonneg_p2(shape, seed, causal):
    """Paper Eq. 10: a_ij >= 0 and rows sum to 1 — structural for p=2
    (min f = f(-1) = 1/2 > 0)."""
    b, h, n, d = shape
    q, k, _ = _qkv(seed, b, h, n, d)
    a = fastmax_attention_matrix_ref(q, k, p=2, causal=causal)
    assert float(jnp.min(a)) >= 0.0
    rows = jnp.sum(a, axis=-1)
    if causal:
        np.testing.assert_allclose(np.asarray(rows), 1.0, rtol=1e-9)
    else:
        np.testing.assert_allclose(np.asarray(rows), 1.0, rtol=1e-9)


@settings(max_examples=20, deadline=None)
@given(shape=_shapes, seed=st.integers(0, 2**20), p=st.sampled_from([1, 2]))
def test_causality(shape, seed, p):
    """Output at position t must not depend on tokens > t."""
    b, h, n, d = shape
    q, k, v = _qkv(seed, b, h, n, d)
    out = fastmax_attention(q, k, v, p=p, causal=True, impl="chunked",
                            chunk_size=5)
    t = max(1, n // 2)
    rng = np.random.default_rng(seed + 1)
    k2 = k.at[:, :, t:].set(jnp.asarray(rng.normal(size=k[:, :, t:].shape)))
    v2 = v.at[:, :, t:].set(jnp.asarray(rng.normal(size=v[:, :, t:].shape)))
    out2 = fastmax_attention(q, k2, v2, p=p, causal=True, impl="chunked",
                             chunk_size=5)
    np.testing.assert_allclose(np.asarray(out[:, :, :t]),
                               np.asarray(out2[:, :, :t]),
                               rtol=1e-9, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(shape=_shapes, seed=st.integers(0, 2**20))
def test_linearity_in_v(shape, seed):
    """O = A V is linear in V (A independent of V)."""
    b, h, n, d = shape
    q, k, v = _qkv(seed, b, h, n, d)
    v2 = jnp.asarray(np.random.default_rng(seed + 2).normal(
        size=v.shape))
    a, bb = 0.7, -1.3
    lhs = fastmax_attention(q, k, a * v + bb * v2, p=2, causal=True,
                            impl="chunked", chunk_size=4)
    rhs = a * fastmax_attention(q, k, v, p=2, causal=True, impl="chunked",
                                chunk_size=4) \
        + bb * fastmax_attention(q, k, v2, p=2, causal=True, impl="chunked",
                                 chunk_size=4)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-8, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(shape=_shapes, seed=st.integers(0, 2**20))
def test_key_permutation_equivariance_noncausal(shape, seed):
    """Noncausal fastmax is symmetric under permuting the key/value set."""
    b, h, n, d = shape
    q, k, v = _qkv(seed, b, h, n, d)
    perm = np.random.default_rng(seed + 3).permutation(n)
    out = fastmax_attention(q, k, v, p=2, causal=False, impl="chunked")
    out_p = fastmax_attention(q, k[:, :, perm], v[:, :, perm], p=2,
                              causal=False, impl="chunked")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                               rtol=1e-9, atol=1e-9)


_shapes_d4 = st.tuples(
    st.integers(1, 2), st.sampled_from([1, 2, 4]),
    st.integers(3, 24), st.sampled_from([4, 8]),
)


@settings(max_examples=15, deadline=None)
@given(shape=_shapes_d4, seed=st.integers(0, 2**20),
       scale=st.floats(0.5, 2.0), shift=st.floats(-2.0, 2.0))
def test_normalization_invariance(shape, seed, scale, shift):
    """Eqs. 5-6 make fastmax invariant to per-token affine q/k rescaling —
    exact up to the normalization epsilon. D=2 is excluded: a token with
    two near-equal components has variance ~0 and is eps-dominated —
    the property requires var >> eps (true at real head dims)."""
    b, h, n, d = shape
    q, k, v = _qkv(seed, b, h, n, d)
    out = fastmax_attention(q, k, v, p=2, causal=True, impl="chunked")
    out2 = fastmax_attention(scale * q + shift, scale * k + shift, v, p=2,
                             causal=True, impl="chunked")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20), n=st.integers(8, 32))
def test_gradient_formula_and_bound_eq15(seed, n):
    """Paper Eq. 15: d o_ij / d s_il = (1+s_il)/Σf · (v_lj - o_ij); the
    stated constant 10‖v‖∞/(2N+3) holds in the paper's regime (s ∈ [0,1],
    N ≥ 6). The FORMULA is verified for arbitrary s."""
    rng = np.random.default_rng(seed)
    d = 4
    v = jnp.asarray(rng.normal(size=(n, d)))

    def o_from_s(s):
        fs = poly_kernel(s, 2)
        return (fs @ v) / jnp.sum(fs, axis=-1, keepdims=True)

    # (a) formula check on arbitrary s
    s_any = jnp.asarray(rng.normal(size=(n, n)))
    jac = jax.jacobian(o_from_s)(s_any)            # [n, d, n, n]
    grads = jnp.einsum("ijil->ijl", jac)           # d o_ij / d s_il
    fs = poly_kernel(s_any, 2)
    o = o_from_s(s_any)
    analytic = ((1.0 + s_any)[:, None, :]
                / jnp.sum(fs, axis=-1)[:, None, None]
                * (v.T[None, :, :] - o[:, :, None]))
    np.testing.assert_allclose(np.asarray(grads), np.asarray(analytic),
                               rtol=1e-8, atol=1e-10)

    # (b) bound check in the paper's regime
    s_pos = jnp.asarray(rng.uniform(0.0, 1.0, size=(n, n)))
    jac = jax.jacobian(o_from_s)(s_pos)
    grads = jnp.abs(jnp.einsum("ijil->ijl", jac))
    bound = 10.0 * jnp.max(jnp.abs(v), axis=0) / (2 * n + 3)
    assert float(jnp.max(grads - bound[None, :, None])) <= 1e-9
