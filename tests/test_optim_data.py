"""Optimizers, schedules, grad utils, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import MemmapDataset, SyntheticLM, make_batch_iterator, \
    write_token_file
from repro.optim import (clip_by_global_norm, compress_decompress,
                         global_norm, make_optimizer, warmup_cosine)


def _quad_problem(opt_name, steps=200, **kw):
    lr = warmup_cosine(0.1, 10, steps)
    init, update = make_optimizer(opt_name, lr, weight_decay=0.0, **kw)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((32, 16), jnp.float32)}
    state = init(params)
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = update(grads, state, params)
    return float(jnp.mean(jnp.square(params["w"] - target)))


def test_adamw_converges():
    assert _quad_problem("adamw") < 1e-3


def test_adamw_int8_converges():
    assert _quad_problem("adamw_int8") < 1e-2


def test_lion_converges():
    assert _quad_problem("lion") < 1e-2


def test_adamw_bf16_master_params():
    lr = warmup_cosine(0.01, 5, 100)
    init, update = make_optimizer("adamw", lr)
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = init(params)
    assert state.master is not None
    assert state.master["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    params2, state2 = update(grads, state, params)
    assert params2["w"].dtype == jnp.bfloat16
    # master must accumulate finer than bf16 steps
    assert float(jnp.max(jnp.abs(state2.master["w"].astype(jnp.float32)
                                 - params["w"].astype(jnp.float32)))) > 0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - np.sqrt(250.0)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_compression_error_feedback_unbiased():
    """Error feedback: the cumulative applied update converges to the true
    cumulative gradient (long-run unbiasedness)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 0.01
    err = None
    applied = jnp.zeros_like(g_true)
    for _ in range(300):
        g_c, err = compress_decompress({"g": g_true}, err, mode="int8")
        applied = applied + g_c["g"]
    rel = float(jnp.linalg.norm(applied / 300 - g_true)
                / jnp.linalg.norm(g_true))
    assert rel < 0.02


def test_synthetic_data_deterministic_by_step():
    d = SyntheticLM(vocab_size=97, seq_len=32, seed=5)
    b1 = d.batch(7, 4)
    b2 = d.batch(7, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(8, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are shifted tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_memmap_dataset_roundtrip(tmp_path):
    toks = np.arange(1000, dtype=np.int32) % 50
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, toks)
    ds = MemmapDataset(path, seq_len=16)
    b = ds.batch(0, 4)
    np.testing.assert_array_equal(b["tokens"][0], toks[:16])
    np.testing.assert_array_equal(b["targets"][0], toks[1:17])
    # host sharding partitions the batch disjointly
    h0 = ds.batch(0, 4, host_id=0, host_count=2)
    h1 = ds.batch(0, 4, host_id=1, host_count=2)
    assert h0["tokens"].shape[0] == 2 and h1["tokens"].shape[0] == 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_batch_iterator_restart_determinism():
    d = SyntheticLM(vocab_size=31, seq_len=8, seed=1)
    it = make_batch_iterator(d, 2, start_step=0)
    seq_a = [next(it) for _ in range(5)]
    it.close()
    it2 = make_batch_iterator(d, 2, start_step=3)
    step, batch = next(it2)
    it2.close()
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], seq_a[3][1]["tokens"])
