"""MoE dispatch properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.moe import apply_moe, init_moe
from repro.models.param import Builder


def _setup(seed=0, **over):
    cfg = get_smoke_config("kimi-k2-1t-a32b", **over)
    b = Builder(jax.random.PRNGKey(seed), jnp.float32)
    init_moe(b, "moe", cfg)
    return cfg, b.params["moe"]


def test_moe_finite_and_shapes():
    cfg, params = _setup()
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 64)),
                    jnp.float32)
    y, aux = apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_moe_grads_flow_to_experts_and_router():
    cfg, params = _setup()
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 32, 64)),
                    jnp.float32)

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["wi_gate"]))) > 0
    assert float(jnp.max(jnp.abs(g["wo"]))) > 0


def test_moe_capacity_drops_overflow():
    """With capacity_factor ~0, (almost) everything is dropped -> output is
    just the shared-expert path (or ~0 without shared experts)."""
    cfg, params = _setup(capacity_factor=1e-9, n_shared_experts=0)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 64, 64)),
                    jnp.float32)
    y, _ = apply_moe(params, x, cfg)
    # capacity = 1 slot total per expert -> at most E*C tokens kept
    kept_norm = float(jnp.sum(jnp.square(y)))
    import dataclasses
    cfg_full = dataclasses.replace(cfg, capacity_factor=8.0)
    y_full, _ = apply_moe(params, x, cfg_full)
    full_norm = float(jnp.sum(jnp.square(y_full)))
    assert kept_norm < 0.55 * full_norm


def test_moe_aux_loss_prefers_balance():
    """Uniform router logits -> aux ~ router_aux_weight (perfect balance);
    collapsed router -> larger aux."""
    cfg, params = _setup()
    t, d = 256, 64
    # positive inputs so a +const router column is ALWAYS the top expert
    x = jnp.asarray(np.abs(np.random.default_rng(3).normal(
        size=(1, t, d))), jnp.float32)
    p_uniform = dict(params)
    p_uniform["router"] = jnp.zeros_like(params["router"])
    _, aux_u = apply_moe(p_uniform, x, cfg)
    p_collapsed = dict(params)
    p_collapsed["router"] = jnp.zeros_like(params["router"]
                                           ).at[:, 0].set(50.0)
    _, aux_c = apply_moe(p_collapsed, x, cfg)
    assert float(aux_c) > 2.0 * float(aux_u)


def test_moe_permutation_consistency():
    """Routing is per-token: permuting tokens permutes outputs (up to
    capacity-order effects — use large capacity so nothing is dropped)."""
    cfg, params = _setup(capacity_factor=8.0)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(1, 32, 64)),
                    jnp.float32)
    y, _ = apply_moe(params, x, cfg)
    perm = np.random.default_rng(5).permutation(32)
    y_p, _ = apply_moe(params, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_p),
                               rtol=2e-4, atol=2e-5)
