"""Sharding rules + an 8-virtual-device dry-run in a subprocess (keeps this
process at 1 device)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_production_mesh  # noqa: F401 (import ok)
from repro.sharding import batch_spec, spec_for
from repro.sharding.rules import DEFAULT_RULES


class _FakeMesh:
    def __init__(self, shape_map):
        self._m = dict(shape_map)

    @property
    def axis_names(self):
        return tuple(self._m)

    @property
    def shape(self):
        return self._m


MESH = _FakeMesh({"data": 16, "model": 16})
MESH3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_basic_tp():
    assert spec_for(("embed", "ff"), (4096, 14336), MESH) \
        == P("data", "model")


def test_spec_divisibility_fallback_kv_heads():
    # kv_heads=8 on model=16 -> replicate (not an error)
    assert spec_for(("embed", "kv_heads", "head_dim"), (4096, 8, 128),
                    MESH) == P("data", None, None)


def test_spec_no_axis_reuse():
    # embed uses data; a second data-mapped dim must not reuse it
    rules = {**DEFAULT_RULES, "ff": ("data",)}
    s = spec_for(("embed", "ff"), (4096, 4096), MESH, rules)
    assert s == P("data", None)


def test_spec_multi_axis_fsdp():
    rules = {**DEFAULT_RULES, "embed": ("pod", "data")}
    assert spec_for(("embed", "ff"), (4096, 14336), MESH3, rules) \
        == P(("pod", "data"), "model")


def test_batch_spec():
    assert batch_spec(MESH, batch_size=256) == P("data")
    assert batch_spec(MESH3, batch_size=256) == P(("pod", "data"))
    assert batch_spec(MESH, batch_size=1) == P(None)
    assert batch_spec(MESH, batch_size=8) == P(None)   # 8 % 16 != 0


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import make_train_step, pick_optimizer
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.models import init_model, input_specs
    from repro.sharding import batch_spec, param_shardings

    cfg = get_smoke_config("{arch}")
    mesh = make_test_mesh((4, 2), ("data", "model"))
    params_shapes, axes = init_model(jax.random.PRNGKey(0), cfg,
                                     abstract=True)
    with mesh:
        psh = param_shardings(axes, params_shapes, mesh)
        _, opt = pick_optimizer(cfg, 1e6)
        opt_shapes = jax.eval_shape(opt[0], params_shapes)
        from repro.launch.dryrun import _opt_shardings
        osh = _opt_shardings(opt_shapes, psh, mesh)
        batch = input_specs(cfg, global_batch=8, seq_len=64, kind="train")
        bsp = batch_spec(mesh, batch_size=8)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh,
            P(*(list(bsp) + [None]*(len(s.shape)-1)))), batch)
        step = make_train_step(cfg, opt)
        lowered = jax.jit(step, in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, None)).lower(
            params_shapes, opt_shapes, batch)
        compiled = lowered.compile()
    res = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({{"flops": res["matmul_flops"],
                      "coll": res["collective_bytes"],
                      "temp": mem.temp_size_in_bytes}}))
""")


@pytest.mark.slow
def test_shard_map_parity_tier_subprocess():
    """The full gate runs the 8-device shard_map/feature-TP parity tier
    (tests/test_shard_map.py) in a subprocess — the same thing
    `make test-shard` runs interactively (the tests skip at 1 device)."""
    import os
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "shard", "-x",
         "tests/test_shard_map.py"],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": "/root", "REPRO_TEST_DEVICES": "8"},
    )
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-2000:])
    assert " skipped" not in out.stdout.splitlines()[-1], out.stdout[-300:]


@pytest.mark.slow
@pytest.mark.parametrize("cell", [
    # the 3 SOFTMAX 32k-decode KV-cache remat warnings stay fixed
    ["--arch", "llama3-405b", "--shape", "decode_32k", "--attn", "softmax",
     "--assert-no-remat"],
    # TP=16 decode routes to the shard_map Pallas decode kernel (no jnp
    # fallback) with a remat-clean partition
    ["--arch", "qwen2.5-32b", "--shape", "decode_32k", "--attn",
     "fastmax2-kernel", "--assert-no-remat", "--assert-kernel-route"],
    # feature-TP scan constraints on the training path stay remat-free
    ["--arch", "qwen2.5-32b", "--shape", "train_4k", "--assert-no-remat"],
    # feature-TP TRAINING routes to the shard_map[feature] Dv-blocked
    # kernels (no chunked-scan fallback), remat-clean
    ["--arch", "qwen2.5-32b", "--shape", "train_4k", "--attn",
     "fastmax2-kernel", "--assert-no-remat", "--assert-kernel-route"],
])
def test_dryrun_sharding_health_gates(cell, tmp_path):
    """Regression gates over the dryrun's machine-checkable diagnostics
    (xla_remat count + attn_routing record) for the shard-native cells."""
    import os
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *cell,
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": "/root"},
    )
    assert out.returncode == 0, (out.stdout[-1500:] + out.stderr[-1500:])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-32b", "kimi-k2-1t-a32b"])
def test_dryrun_8dev_subprocess(arch):
    """End-to-end sharded lower+compile on a 4x2 virtual mesh; collectives
    must appear (TP psums / MoE) and the HLO analyzer must parse them."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(arch=arch)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0
    assert res["coll"] > 0
    assert res["temp"] > 0
