"""repro.attention: spec parsing, registry capability routing, dispatcher
equivalence of every registered backend against the oracle, the unified
decode-state protocol, and the deprecation shims."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import (
    AttentionSpec,
    UnsupportedCapabilityError,
    attention,
    get_backend,
    init_state,
    list_backends,
    prefill,
    resolve,
    step,
)
from repro.core.ref import softmax_attention_ref

jax.config.update("jax_enable_x64", True)


def mk(rng, b, hq, hkv, n, d, dv, dtype=jnp.float64):
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


def test_parse_names():
    assert AttentionSpec.parse("softmax").family == "softmax"
    assert AttentionSpec.parse("fastmax").p == 2
    assert AttentionSpec.parse("fastmax1").p == 1
    assert AttentionSpec.parse("fastmax2").p == 2
    s = AttentionSpec.parse("fastmax1-kernel")
    assert (s.family, s.p, s.impl) == ("fastmax", 1, "kernel")
    assert AttentionSpec.parse(None) == AttentionSpec()
    with pytest.raises(ValueError):
        AttentionSpec.parse("flashmax")


def test_spec_validates():
    with pytest.raises(ValueError):
        AttentionSpec(family="nope")
    with pytest.raises(ValueError):
        AttentionSpec(impl="nope")
    with pytest.raises(ValueError):
        AttentionSpec(p=3)


def test_backend_names_cover_registry():
    """Every spec-reachable backend is registered, and vice versa."""
    reachable = {"softmax"} | {f"fastmax-{i}"
                               for i in ("oracle", "rowwise", "chunked",
                                         "kernel")} \
        | {"hybrid-chunked", "hybrid-kernel"}
    assert set(list_backends()) == reachable


def test_p_derivation_single_source():
    """The old `p = 1 if backend == "fastmax1" else 2` 4x duplication is now
    one field with one legacy mapping."""
    assert AttentionSpec.parse("fastmax1").legacy_name == "fastmax1"
    assert AttentionSpec.parse("fastmax2").legacy_name == "fastmax2"
    assert AttentionSpec(family="softmax").legacy_name == "softmax"


# ---------------------------------------------------------------------------
# dispatcher equivalence: every backend vs the oracle
# ---------------------------------------------------------------------------

ORACLE = AttentionSpec(impl="oracle")
# (B, Hq, Hkv, N, D, Dv): MHA and GQA (g=2, g=4)
EQ_SHAPES = [(1, 2, 2, 33, 8, 8), (2, 4, 2, 29, 8, 8), (1, 8, 2, 24, 4, 4)]


@pytest.mark.parametrize("shape", EQ_SHAPES)
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("p", [1, 2])
@pytest.mark.parametrize("impl", ["rowwise", "chunked", "kernel"])
def test_fastmax_backends_match_oracle(impl, p, causal, shape):
    rng = np.random.default_rng(hash((impl, p, causal, shape)) % 2**31)
    q, k, v = mk(rng, *shape)
    ref = attention(q, k, v, dataclasses.replace(ORACLE, p=p), causal=causal)
    out = attention(q, k, v,
                    AttentionSpec(family="fastmax", p=p, impl=impl,
                                  chunk_size=16),
                    causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("shape", EQ_SHAPES)
def test_softmax_backend_matches_reference(causal, shape):
    rng = np.random.default_rng(hash((causal, shape)) % 2**31)
    q, k, v = mk(rng, *shape)
    out = attention(q, k, v, AttentionSpec(family="softmax"), causal=causal)
    # reference handles GQA by explicit broadcast
    g = q.shape[1] // k.shape[1]
    kb = jnp.repeat(k, g, axis=1)
    vb = jnp.repeat(v, g, axis=1)
    ref = softmax_attention_ref(q, kb, vb, causal=causal)
    # production softmax accumulates in f32 regardless of input dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# capability routing
# ---------------------------------------------------------------------------


def test_dropout_on_chunked_routes_to_rowwise():
    spec = AttentionSpec(impl="chunked", dropout_rate=0.25)
    assert resolve(spec, causal=True, dropout=True).name == "fastmax-rowwise"
    # and the dispatched result equals calling rowwise directly
    rng = np.random.default_rng(0)
    q, k, v = mk(rng, 1, 2, 2, 16, 4, 4, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    out = attention(q, k, v, spec, causal=True, rng=key)
    direct = attention(q, k, v, dataclasses.replace(spec, impl="rowwise"),
                       causal=True, rng=key)
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               rtol=1e-6, atol=1e-6)


def test_dropout_strict_raises():
    spec = AttentionSpec(impl="chunked", dropout_rate=0.25)
    rng = np.random.default_rng(0)
    q, k, v = mk(rng, 1, 2, 2, 16, 4, 4, dtype=jnp.float32)
    with pytest.raises(UnsupportedCapabilityError):
        attention(q, k, v, spec, causal=True, rng=jax.random.PRNGKey(0),
                  strict=True)


def test_kernel_dropout_routes_through_chain_to_rowwise():
    spec = AttentionSpec(impl="kernel", dropout_rate=0.25)
    assert resolve(spec, causal=True, dropout=True).name == "fastmax-rowwise"


def test_kv_mask_on_kernel_routes_to_chunked():
    spec = AttentionSpec(impl="kernel")
    assert resolve(spec, causal=False, kv_mask=True).name == "fastmax-chunked"


def test_no_capable_backend_raises():
    # dropout has no softmax-family implementation
    spec = AttentionSpec(family="softmax", dropout_rate=0.25)
    with pytest.raises(UnsupportedCapabilityError):
        resolve(spec, causal=True, dropout=True)


def test_kernel_off_platform_still_serves():
    """Off-TPU the kernel backend interprets instead of rerouting."""
    b = resolve(AttentionSpec(impl="kernel"), causal=True)
    assert b.name == "fastmax-kernel"


def test_resolution_is_logged(caplog):
    import repro.attention.registry as R
    R._LOGGED.clear()
    with caplog.at_level("INFO", logger="repro.attention"):
        resolve(AttentionSpec(impl="chunked", dropout_rate=0.5),
                causal=True, dropout=True)
    assert any("routing to fastmax-rowwise" in r.message
               for r in caplog.records)


# ---------------------------------------------------------------------------
# unified decode-state protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    AttentionSpec(family="fastmax", p=2, chunk_size=8),
    AttentionSpec(family="fastmax", p=1, chunk_size=8),
    AttentionSpec(family="softmax"),
], ids=["fastmax2", "fastmax1", "softmax"])
def test_prefill_then_step_equals_full_causal(spec):
    """prefill(prompt) + step(token)* must reproduce full causal attention
    for BOTH state families (moments and KV cache)."""
    rng = np.random.default_rng(7)
    b, hq, hkv, n, d = 2, 4, 2, 21, 8
    q, k, v = mk(rng, b, hq, hkv, n, d, d)
    full = attention(
        q, k, v,
        spec if spec.family == "softmax"
        else dataclasses.replace(spec, impl="oracle"),
        causal=True)
    st = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                    v_head_dim=d, max_len=n, dtype=jnp.float64)
    pre = 13
    o_pre, st = prefill(q[:, :, :pre], k[:, :, :pre], v[:, :, :pre], spec,
                        state=st)
    np.testing.assert_allclose(np.asarray(o_pre), np.asarray(full[:, :, :pre]),
                               rtol=1e-6, atol=1e-7)
    for t in range(pre, n):
        o_t, st = step(st, q[:, :, t:t + 1], k[:, :, t:t + 1],
                       v[:, :, t:t + 1], spec)
        np.testing.assert_allclose(np.asarray(o_t[:, :, 0]),
                                   np.asarray(full[:, :, t]),
                                   rtol=1e-6, atol=1e-7)


def test_softmax_prefill_kv_mask_persists_through_steps():
    """Padding keys masked at prefill must stay invisible in later decode
    steps (the mask is carried in the KV cache, not rebuilt from length)."""
    rng = np.random.default_rng(11)
    spec = AttentionSpec(family="softmax")
    b, h, n, d = 1, 2, 8, 4
    q, k, v = mk(rng, b, h, h, n, d, d)
    pad = 3  # prompt = 5 real tokens + 3 padding
    mask = jnp.concatenate([jnp.ones((b, h, n - pad)),
                            jnp.zeros((b, h, pad))], axis=-1)
    st = init_state(spec, batch=b, n_kv_heads=h, q_head_dim=d, v_head_dim=d,
                    max_len=n + 2, dtype=jnp.float64)
    _, st = prefill(q, k, v, spec, state=st, kv_mask=mask)
    # reference: same cache contents but padding rows dropped entirely
    st2 = init_state(spec, batch=b, n_kv_heads=h, q_head_dim=d, v_head_dim=d,
                     max_len=n + 2, dtype=jnp.float64)
    _, st2 = prefill(q[:, :, :n - pad], k[:, :, :n - pad], v[:, :, :n - pad],
                     spec, state=st2)
    q1, k1, v1 = mk(rng, b, h, h, 1, d, d)
    o_masked, _ = step(st, q1, k1, v1, spec)
    # the truncated reference appends at a different slot; align lengths:
    # masked cache has length n with 3 dead slots -> same attention set
    o_trunc, _ = step(st2, q1, k1, v1, spec)
    np.testing.assert_allclose(np.asarray(o_masked), np.asarray(o_trunc),
                               rtol=1e-6, atol=1e-7)


def test_fastmax_resumable_prefill_3d_kv_mask_bitwise():
    """Chunked (offset=...) prefill with a per-head [B, Hkv, N] kv_mask
    must be BITWISE equal to the whole-prompt offset prefill: the carried
    moments seed the scan exactly, and per-head masking survives the
    split."""
    rng = np.random.default_rng(21)
    spec = AttentionSpec(family="fastmax", p=2, chunk_size=16)
    b, hq, hkv, n, d = 2, 4, 2, 32, 8
    q, k, v = mk(rng, b, hq, hkv, n, d, d)
    mask = (rng.random((b, hkv, n)) < 0.7).astype(np.float64)
    mask[..., 0] = 1.0                 # keep row 0 denominators non-degenerate
    mask = jnp.asarray(mask)

    def fresh():
        return init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                          v_head_dim=d, max_len=n, dtype=jnp.float64)

    zero = jnp.asarray(0, jnp.int32)
    o_full, st_full = prefill(q, k, v, spec, state=fresh(), kv_mask=mask,
                              offset=zero)
    c = 16                             # split exactly at a chunk boundary
    st = fresh()
    o1, st = prefill(q[:, :, :c], k[:, :, :c], v[:, :, :c], spec, state=st,
                     kv_mask=mask[:, :, :c], offset=zero)
    o2, st = prefill(q[:, :, c:], k[:, :, c:], v[:, :, c:], spec, state=st,
                     kv_mask=mask[:, :, c:],
                     offset=jnp.asarray(c, jnp.int32))
    got = jnp.concatenate([o1, o2], axis=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(o_full))
    for name, a, ref in zip(st.moments._fields, st.moments,
                            st_full.moments):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(ref),
                                      err_msg=name)


def test_softmax_resumable_prefill_3d_kv_mask_matches_whole():
    """Same split through the KV-cache resume path: outputs match the
    whole-prompt call and a later decode step sees identical caches (the
    per-head mask rides the cache's mask lane across the resume)."""
    rng = np.random.default_rng(22)
    spec = AttentionSpec(family="softmax")
    b, hq, hkv, n, d = 1, 4, 2, 32, 8
    q, k, v = mk(rng, b, hq, hkv, n, d, d)
    mask = (rng.random((b, hkv, n)) < 0.7).astype(np.float64)
    mask[..., 0] = 1.0
    mask = jnp.asarray(mask)

    def fresh():
        return init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                          v_head_dim=d, max_len=n + 2, dtype=jnp.float64)

    o_full, st_full = prefill(q, k, v, spec, state=fresh(), kv_mask=mask)
    c = 16
    st = fresh()
    o1, st = prefill(q[:, :, :c], k[:, :, :c], v[:, :, :c], spec, state=st,
                     kv_mask=mask[:, :, :c],
                     offset=jnp.asarray(0, jnp.int32))
    o2, st = prefill(q[:, :, c:], k[:, :, c:], v[:, :, c:], spec, state=st,
                     kv_mask=mask[:, :, c:],
                     offset=jnp.asarray(c, jnp.int32))
    got = jnp.concatenate([o1, o2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(o_full),
                               rtol=2e-5, atol=2e-5)
    q1, k1, v1 = mk(rng, b, hq, hkv, 1, d, d)
    o_a, _ = step(st, q1, k1, v1, spec)
    o_b, _ = step(st_full, q1, k1, v1, spec)
    np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b),
                               rtol=2e-5, atol=2e-5)


def test_parse_rejects_softmax_impl_suffix():
    with pytest.raises(ValueError):
        AttentionSpec.parse("softmax-kernel")


def test_step_with_wrong_family_state_raises_clearly():
    st = init_state(AttentionSpec(family="softmax"), batch=1, n_kv_heads=1,
                    q_head_dim=4, v_head_dim=4, max_len=4)
    rng = np.random.default_rng(0)
    q, k, v = mk(rng, 1, 1, 1, 1, 4, 4, dtype=jnp.float32)
    with pytest.raises(ValueError, match="different attention family"):
        step(st, q, k, v, AttentionSpec())


def test_init_state_shapes():
    soft = init_state(AttentionSpec(family="softmax"), batch=2, n_kv_heads=3,
                      q_head_dim=8, v_head_dim=4, max_len=10)
    assert soft.moments is None
    assert soft.kv.k.shape == (2, 3, 10, 8)
    assert soft.kv.v.shape == (2, 3, 10, 4)
    fast = init_state(AttentionSpec(), batch=2, n_kv_heads=3, q_head_dim=8,
                      v_head_dim=4, max_len=10)
    assert fast.kv is None
    assert fast.moments.m2.shape == (2, 3, 8, 8, 4)


def test_init_state_requires_decode_capability():
    with pytest.raises(ValueError):
        init_state(AttentionSpec(impl="oracle"), batch=1, n_kv_heads=1,
                   q_head_dim=4, v_head_dim=4, max_len=4)


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_modelconfig_legacy_string_pair_shim():
    from repro.models.transformer import ModelConfig

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ModelConfig(attn_backend="fastmax1", attn_impl="kernel")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert (cfg.attn.family, cfg.attn.p, cfg.attn.impl) == \
        ("fastmax", 1, "kernel")
    # dataclasses.replace with the legacy kwarg still works
    cfg2 = dataclasses.replace(cfg, attn_backend="softmax")
    assert cfg2.attn.family == "softmax"
    # plain replace of unrelated fields must NOT disturb the spec
    cfg3 = dataclasses.replace(cfg, d_model=128)
    assert cfg3.attn == cfg.attn


def test_core_fastmax_attention_shim_matches_dispatcher():
    from repro.core import fastmax_attention

    rng = np.random.default_rng(9)
    q, k, v = mk(rng, 1, 4, 2, 18, 4, 4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = fastmax_attention(q, k, v, p=2, causal=True, impl="chunked",
                                chunk_size=8)
    new = attention(q, k, v, AttentionSpec(p=2, impl="chunked", chunk_size=8),
                    causal=True)
    np.testing.assert_allclose(np.asarray(old), np.asarray(new))


def test_core_fastmaxconfig_alias():
    import repro.core as core

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cls = core.FastmaxConfig
    assert cls is AttentionSpec
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_chunk_size_inheritance_from_model_config():
    from repro.models.transformer import ModelConfig

    cfg = ModelConfig(chunk_size=64)
    assert cfg.attn.chunk_size is None
    assert cfg.attn_spec.chunk_size == 64
    cfg2 = dataclasses.replace(cfg, chunk_size=16)
    assert cfg2.attn_spec.chunk_size == 16  # replace() must not freeze it
    pinned = ModelConfig(attn=AttentionSpec(chunk_size=32), chunk_size=64)
    assert pinned.attn_spec.chunk_size == 32


def test_registry_backend_lookup_error():
    with pytest.raises(KeyError):
        get_backend("does-not-exist")


# ---------------------------------------------------------------------------
# decode_kernel capability + native-state kernel routing
# ---------------------------------------------------------------------------


def test_decode_kernel_capability_declared():
    assert get_backend("fastmax-kernel").caps.decode_kernel
    assert not get_backend("fastmax-chunked").caps.decode_kernel
    assert not get_backend("softmax").caps.decode_kernel


def test_use_decode_kernel_env_routing(monkeypatch, caplog):
    import logging

    from repro.attention.state import use_decode_kernel

    spec = AttentionSpec(family="fastmax", impl="kernel")
    caplog.set_level(logging.INFO, logger="repro.attention")
    # off-TPU default: logged fallback to the jnp moment step
    monkeypatch.delenv("REPRO_DECODE_KERNEL", raising=False)
    if jax.default_backend() != "tpu":
        assert not use_decode_kernel(spec)
    # forced: kernel path even off-TPU (interpret)
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "1")
    assert use_decode_kernel(spec)
    # disabled: never the kernel
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "0")
    assert not use_decode_kernel(spec)
    # only backends with the capability route to the kernel
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "1")
    assert not use_decode_kernel(AttentionSpec(family="fastmax",
                                               impl="chunked"))
    assert not use_decode_kernel(AttentionSpec(family="softmax"))
    from repro.attention import registry as _reg
    assert any("native-state kernel" in m for m in _reg._LOGGED)


def test_prefill_step_kernel_path_matches_oracle(monkeypatch):
    """The forced kernel decode path (prefill carry emitted by the forward
    kernel + fused decode steps) reproduces full causal attention."""
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "1")
    spec = AttentionSpec(family="fastmax", p=2, impl="kernel", chunk_size=8)
    rng = np.random.default_rng(9)
    b, hq, hkv, n, d = 1, 4, 2, 21, 8
    q, k, v = mk(rng, b, hq, hkv, n, d, d)
    full = attention(q, k, v, dataclasses.replace(spec, impl="oracle"),
                     causal=True)
    st = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                    v_head_dim=d, max_len=n, dtype=jnp.float64)
    pre = 13
    o_pre, st = prefill(q[:, :, :pre], k[:, :, :pre], v[:, :, :pre], spec,
                        state=st)
    np.testing.assert_allclose(np.asarray(o_pre),
                               np.asarray(full[:, :, :pre]),
                               rtol=1e-6, atol=1e-7)
    for t in range(pre, n):
        o_t, st = step(st, q[:, :, t:t + 1], k[:, :, t:t + 1],
                       v[:, :, t:t + 1], spec)
        np.testing.assert_allclose(np.asarray(o_t[:, :, 0]),
                                   np.asarray(full[:, :, t]),
                                   rtol=1e-6, atol=1e-7)
