"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only the dry-run (and the subprocess sharding tests)
force host platform device counts."""
import jax
import numpy as np
import pytest


def pytest_collection_modifyitems(items):
    # tier-1 verify loop = everything that isn't a multi-minute subprocess
    # compile; `make test-fast` runs `-m "tier1"`.
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session", autouse=True)
def _x64():
    # kernels/core are validated in f64 where exactness matters; individual
    # tests opt in via the helpers below rather than globally.
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_qkv(rng, b, hq, hkv, n, d, dv, dtype=np.float32, normalized=False):
    import jax.numpy as jnp
    from repro.core.ref import normalize_qk
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    if normalized:
        q, k = normalize_qk(q), normalize_qk(k)
    return q, k, v
