"""Shared test fixtures. NOTE: no XLA_FLAGS by default — smoke tests and
benches must see 1 device; only the dry-run (and the subprocess sharding
tests) force host platform device counts.

Multi-device tier (`make test-shard`): setting REPRO_TEST_DEVICES=N in the
environment makes this conftest inject
`--xla_force_host_platform_device_count=N` BEFORE jax is imported (the flag
is read at backend init, so it cannot be a fixture) — the shard_map parity
tests in test_shard_map.py then see N host devices; without the variable
they skip via the `shard_devices` fixture and the full gate covers them
through a subprocess wrapper instead.
"""
import os

if os.environ.get("REPRO_TEST_DEVICES"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count="
            + os.environ["REPRO_TEST_DEVICES"]).strip()

import jax
import numpy as np
import pytest


def pytest_collection_modifyitems(items):
    # tier-1 verify loop = everything that isn't a multi-minute subprocess
    # compile; `make test-fast` runs `-m "tier1"`.
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session", autouse=True)
def _x64():
    # kernels/core are validated in f64 where exactness matters; individual
    # tests opt in via the helpers below rather than globally.
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def shard_devices():
    """>= 8 host devices, or skip (run this tier via `make test-shard`)."""
    if jax.device_count() < 8:
        pytest.skip("needs >= 8 devices: run `make test-shard` "
                    "(REPRO_TEST_DEVICES=8)")
    return jax.devices()[:8]


def make_qkv(rng, b, hq, hkv, n, d, dv, dtype=np.float32, normalized=False):
    import jax.numpy as jnp
    from repro.core.ref import normalize_qk
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    if normalized:
        q, k = normalize_qk(q), normalize_qk(k)
    return q, k, v
