"""Hybrid near/far-field backend tier (`hybrid` marker; `make test-hybrid`).

The load-bearing contracts:

* the hybrid operator (exact banded softmax over the last `window` causal
  positions + fastmax p=2 moments over everything older, ONE shared
  normalizer) matches the composed dense oracle at f64 — forward AND
  grads — for the chunked scan and the Pallas kernel (interpret mode);
* the window edges degenerate correctly: w=0 is BITWISE fastmax, and
  w >= N reproduces exact softmax over the normalized scores;
* prefill + step decode is lockstep with the one-shot causal forward
  (the decode state carries both legs: moments + a rolling W-slot
  window cache), including resumable chunked prefill;
* the serving engine produces exactly the tokens `generate()` produces
  for hybrid-backed models, including slot reuse.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv
from repro.attention import (
    AttentionSpec,
    attention,
    get_backend,
    init_state,
    prefill,
    step,
)
from repro.core.hybrid import (
    effective_window,
    fastmax_causal_chunked,
    hybrid_attention_ref,
    hybrid_causal_chunked,
)
from repro.core.ref import normalize_qk, softmax_attention_ref

jax.config.update("jax_enable_x64", True)

pytestmark = pytest.mark.hybrid

# (B, Hq, Hkv, N, D, Dv): MHA and GQA
SHAPES = [(1, 2, 2, 33, 8, 8), (2, 4, 2, 29, 8, 8)]


# ---------------------------------------------------------------------------
# operator equivalence vs the composed dense oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [1, 5, 16])
@pytest.mark.parametrize("shape", SHAPES)
def test_chunked_matches_composed_oracle(shape, window):
    rng = np.random.default_rng(hash((shape, window)) % 2**31)
    q, k, v = make_qkv(rng, *shape, dtype=np.float64, normalized=True)
    # chunk_size >= window: w_eff = min(window, C) stays the nominal window
    ref = hybrid_attention_ref(q, k, v, window=window, normalize=False)
    out = hybrid_causal_chunked(q, k, v, window=window, chunk_size=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-10, atol=1e-10)


def test_window_clamped_to_chunk_matches_clamped_oracle():
    """window > chunk_size clamps to w_eff = chunk_size — the output equals
    the oracle run at the CLAMPED window, not the nominal one."""
    rng = np.random.default_rng(41)
    q, k, v = make_qkv(rng, 1, 2, 2, 33, 8, 8, dtype=np.float64,
                       normalized=True)
    ref = hybrid_attention_ref(q, k, v, window=8, normalize=False)
    out = hybrid_causal_chunked(q, k, v, window=16, chunk_size=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("window", [1, 7])
def test_chunked_grads_match_composed_oracle(window):
    rng = np.random.default_rng(5)
    q, k, v = make_qkv(rng, 2, 4, 2, 29, 8, 8, dtype=np.float64,
                       normalized=True)
    cot = jnp.asarray(rng.normal(size=(2, 4, 29, 8)), jnp.float64)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) * cot)

    g_ref = jax.grad(loss(lambda q, k, v: hybrid_attention_ref(
        q, k, v, window=window, normalize=False)), argnums=(0, 1, 2))(q, k, v)
    g_out = jax.grad(loss(lambda q, k, v: hybrid_causal_chunked(
        q, k, v, window=window, chunk_size=8)), argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_out, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-10, err_msg=name)


@pytest.mark.parametrize("window", [1, 5, 16])
def test_kernel_matches_composed_oracle(window):
    from repro.kernels.hybrid_causal import hybrid_causal_pallas
    rng = np.random.default_rng(hash(("kernel", window)) % 2**31)
    q, k, v = make_qkv(rng, 1, 4, 2, 29, 8, 8, dtype=np.float64,
                       normalized=True)
    ref = hybrid_attention_ref(q, k, v, window=window, normalize=False)
    out = hybrid_causal_pallas(q, k, v, window=window, chunk_size=16,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-10, atol=1e-10)


def test_kernel_trainable_grads_match_composed_oracle():
    """The custom-VJP wrapper (Pallas forward, §2.5-style reverse scan
    backward) must agree with the oracle's autodiff grads at f64."""
    from repro.kernels import ops as kernel_ops
    rng = np.random.default_rng(6)
    q, k, v = make_qkv(rng, 1, 4, 2, 29, 8, 8, dtype=np.float64,
                       normalized=True)
    cot = jnp.asarray(rng.normal(size=(1, 4, 29, 8)), jnp.float64)
    w = 7

    g_ref = jax.grad(
        lambda q, k, v: jnp.sum(hybrid_attention_ref(
            q, k, v, window=w, normalize=False) * cot),
        argnums=(0, 1, 2))(q, k, v)
    g_out = jax.grad(
        lambda q, k, v: jnp.sum(kernel_ops.hybrid(
            q, k, v, window=w, chunk_size=8, interpret=True) * cot),
        argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_out, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-10, atol=1e-10, err_msg=name)


# ---------------------------------------------------------------------------
# window edges
# ---------------------------------------------------------------------------


def test_window_zero_is_bitwise_fastmax():
    """w_eff = 0 must delegate to the fastmax scan with NO numeric drift —
    the correction term is skipped entirely, not computed-and-masked."""
    rng = np.random.default_rng(7)
    q, k, v = make_qkv(rng, 1, 4, 2, 33, 8, 8, dtype=np.float32,
                       normalized=True)
    base = fastmax_causal_chunked(q, k, v, p=2, chunk_size=8)
    out = hybrid_causal_chunked(q, k, v, window=0, chunk_size=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_window_covers_sequence_is_exact_softmax():
    """w_eff >= N leaves no far-field token: the output IS softmax over
    the normalized scores (hq == hkv: the dense softmax reference is not
    GQA-aware; scale=1.0: hybrid scores are plain q_hat . k_hat)."""
    rng = np.random.default_rng(8)
    n = 24
    q, k, v = make_qkv(rng, 1, 2, 2, n, 8, 8, dtype=np.float64,
                       normalized=True)
    ref = softmax_attention_ref(q, k, v, causal=True, scale=1.0)
    out = hybrid_causal_chunked(q, k, v, window=n, chunk_size=n,
                                denom_eps=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-12, atol=1e-12)


def test_effective_window_clamps_to_chunk():
    assert effective_window(64, 16) == 16
    assert effective_window(5, 16) == 5
    assert effective_window(-3, 16) == 0
    assert effective_window(0, 16) == 0


# ---------------------------------------------------------------------------
# dispatcher + registry
# ---------------------------------------------------------------------------


def test_parse_hybrid_names():
    s = AttentionSpec.parse("hybrid2-kernel")
    assert (s.family, s.p, s.impl) == ("hybrid", 2, "kernel")
    assert AttentionSpec.parse("hybrid").family == "hybrid"
    assert AttentionSpec.parse("hybrid2-chunked").family == "hybrid"
    with pytest.raises(ValueError):
        AttentionSpec.parse("hybrid2-rowwise")


def test_hybrid_backends_declare_capabilities():
    ch = get_backend("hybrid-chunked")
    ke = get_backend("hybrid-kernel")
    assert ch.caps.decode and ke.caps.decode
    assert not ch.caps.noncausal            # near-field band is causal-only
    assert not ch.caps.decode_kernel and not ke.caps.decode_kernel
    assert ke.fallback == "hybrid-chunked"


@pytest.mark.parametrize("impl", ["chunked", "kernel"])
def test_dispatcher_matches_ref(impl):
    """attention() with a hybrid spec (normalization handled by the
    backend) matches the dense reference on raw q/k."""
    rng = np.random.default_rng(hash(impl) % 2**31)
    q, k, v = make_qkv(rng, 2, 4, 2, 29, 8, 8, dtype=np.float64)
    spec = AttentionSpec(family="hybrid", impl=impl, window=9, chunk_size=16)
    ref = hybrid_attention_ref(q, k, v, window=9, normalize=True)
    out = attention(q, k, v, spec, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-10, atol=1e-10)


def test_dispatcher_noncausal_hybrid_raises():
    rng = np.random.default_rng(0)
    q, k, v = make_qkv(rng, 1, 2, 2, 8, 4, 4, dtype=np.float32)
    spec = AttentionSpec(family="hybrid", impl="chunked")
    from repro.attention import UnsupportedCapabilityError
    with pytest.raises(UnsupportedCapabilityError):
        attention(q, k, v, spec, causal=False, strict=True)


# ---------------------------------------------------------------------------
# decode protocol: prefill + step lockstep with the one-shot forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 4, 64], ids=["w0", "w4", "wfull"])
def test_prefill_then_step_lockstep(window):
    """prefill(prompt) + step(token)* reproduces the one-shot causal
    forward for every window regime (pure fastmax, banded, full band)."""
    rng = np.random.default_rng(11)
    b, hq, hkv, n, d = 2, 4, 2, 21, 8
    q, k, v = make_qkv(rng, b, hq, hkv, n, d, d, dtype=np.float64)
    spec = AttentionSpec(family="hybrid", impl="chunked", window=window,
                         chunk_size=8)
    full = attention(q, k, v, spec, causal=True)
    st = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                    v_head_dim=d, max_len=n, dtype=jnp.float64)
    pre = 13
    o_pre, st = prefill(q[:, :, :pre], k[:, :, :pre], v[:, :, :pre], spec,
                        state=st)
    np.testing.assert_allclose(np.asarray(o_pre),
                               np.asarray(full[:, :, :pre]),
                               rtol=1e-10, atol=1e-10)
    for t in range(pre, n):
        o_t, st = step(st, q[:, :, t:t + 1], k[:, :, t:t + 1],
                       v[:, :, t:t + 1], spec)
        np.testing.assert_allclose(np.asarray(o_t[:, :, 0]),
                                   np.asarray(full[:, :, t]),
                                   rtol=1e-10, atol=1e-10)


def test_decode_256_steps_lockstep():
    """Long-horizon drift check: 256 decode steps after a 32-token prefill
    stay lockstep with the one-shot forward (the rolling window cache and
    the moment fold never disagree about which leg owns a token)."""
    rng = np.random.default_rng(12)
    b, h, d = 1, 2, 8
    n, pre = 288, 32
    q, k, v = make_qkv(rng, b, h, h, n, d, d, dtype=np.float64)
    spec = AttentionSpec(family="hybrid", impl="chunked", window=8,
                         chunk_size=16)
    full = attention(q, k, v, spec, causal=True)
    st = init_state(spec, batch=b, n_kv_heads=h, q_head_dim=d, v_head_dim=d,
                    max_len=n, dtype=jnp.float64)
    _, st = prefill(q[:, :, :pre], k[:, :, :pre], v[:, :, :pre], spec,
                    state=st)

    @jax.jit
    def one(st, qkv):
        qt, kt, vt = qkv
        return step(st, qt, kt, vt, spec)

    outs = []
    for t in range(pre, n):
        o_t, st = one(st, (q[:, :, t:t + 1], k[:, :, t:t + 1],
                           v[:, :, t:t + 1]))
        outs.append(o_t[:, :, 0])
    got = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, :, pre:]),
                               rtol=1e-10, atol=1e-10)


def test_resumable_offset_prefill_matches_whole():
    """Chunked (offset=...) prefill split at a chunk boundary matches the
    whole-prompt call: the carried moments AND the carried window cache
    seed the scan exactly."""
    rng = np.random.default_rng(13)
    b, hq, hkv, n, d = 2, 4, 2, 32, 8
    q, k, v = make_qkv(rng, b, hq, hkv, n, d, d, dtype=np.float64)
    spec = AttentionSpec(family="hybrid", impl="chunked", window=8,
                         chunk_size=16)

    def fresh():
        return init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                          v_head_dim=d, max_len=n, dtype=jnp.float64)

    zero = jnp.asarray(0, jnp.int32)
    o_full, st_full = prefill(q, k, v, spec, state=fresh(), offset=zero)
    c = 16
    st = fresh()
    o1, st = prefill(q[:, :, :c], k[:, :, :c], v[:, :, :c], spec, state=st,
                     offset=zero)
    o2, st = prefill(q[:, :, c:], k[:, :, c:], v[:, :, c:], spec, state=st,
                     offset=jnp.asarray(c, jnp.int32))
    got = jnp.concatenate([o1, o2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(o_full),
                               rtol=1e-12, atol=1e-12)
    for name, a, ref in zip(st.moments._fields, st.moments, st_full.moments):
        np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                                   rtol=1e-12, atol=1e-12, err_msg=name)
    for name in ("k", "v", "mask"):
        np.testing.assert_allclose(np.asarray(getattr(st.kv, name)),
                                   np.asarray(getattr(st_full.kv, name)),
                                   rtol=1e-12, atol=1e-12, err_msg=name)
    # and a later decode step sees identical state
    q1, k1, v1 = make_qkv(rng, b, hq, hkv, 1, d, d, dtype=np.float64)
    o_a, _ = step(st, q1, k1, v1, spec)
    o_b, _ = step(st_full, q1, k1, v1, spec)
    np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b),
                               rtol=1e-12, atol=1e-12)


def test_window_zero_state_has_no_kv_leg():
    spec = AttentionSpec(family="hybrid", impl="chunked", window=0)
    st = init_state(spec, batch=1, n_kv_heads=2, q_head_dim=4, v_head_dim=4,
                    max_len=8)
    assert st.kv is None and st.moments is not None


# ---------------------------------------------------------------------------
# serving engine parity (slot-indexed hybrid state: moments + window cache)
# ---------------------------------------------------------------------------


def _serve_setup(spec_name):
    from repro.configs import get_smoke_config
    from repro.models import init_model
    cfg = get_smoke_config("qwen3-1.7b")
    cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(spec_name))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve_ref(params, cfg, prompt, gen, max_len):
    from repro.launch.serve import generate
    return np.asarray(generate(params, cfg, jnp.asarray(prompt[None]), gen,
                               max_len=max_len))[0]


def test_engine_parity_hybrid():
    """Staggered admissions + ragged prompts through the engine produce
    exactly the tokens generate() produces with the hybrid backend (the
    slot pool must scatter/gather BOTH state legs)."""
    from repro.serve import ServeEngine
    cfg, params = _serve_setup("hybrid2-chunked")
    rng = np.random.default_rng(21)
    p0 = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 23).astype(np.int32)
    G = 6
    ref0 = _serve_ref(params, cfg, p0, G, 64)
    ref1 = _serve_ref(params, cfg, p1, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    r0 = eng.submit(p0, G)
    outs = {}
    for _ in range(3):
        for f in eng.step():
            outs[f.rid] = f.tokens
    r1 = eng.submit(p1, G)
    outs.update(eng.run())
    np.testing.assert_array_equal(outs[r0], ref0)
    np.testing.assert_array_equal(outs[r1], ref1)


def test_engine_slot_reuse_hybrid():
    """max_slots=1 serving 3 queued hybrid requests: each admit must fully
    overwrite the evicted slot's window cache AND moments — stale band
    tokens from the previous tenant must not leak into the next."""
    from repro.serve import ServeEngine
    cfg, params = _serve_setup("hybrid2-chunked")
    rng = np.random.default_rng(22)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (19, 40, 8)]
    G = 4
    refs = [_serve_ref(params, cfg, p, G, 64) for p in prompts]
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    rids = [eng.submit(p, G) for p in prompts]
    outs = eng.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)


# ---------------------------------------------------------------------------
# sharded execution (skips without REPRO_TEST_DEVICES=8)
# ---------------------------------------------------------------------------


# (mesh shape, hkv, hq) per partitioning mode — same matrix as
# test_shard_map.py: heads needs Hkv % tp == 0, feature exercises GQA kv
# heads that do NOT divide the model axis
_SHARD_MODES = {
    "heads": dict(mesh=(2, 4), hkv=4, hq=8),
    "feature": dict(mesh=(2, 4), hkv=2, hq=4),
}


@pytest.mark.parametrize("mode", sorted(_SHARD_MODES))
def test_hybrid_sharded_matches_single_device(shard_devices, mode):
    """hybrid_sharded heads/feature modes (fwd + grads) match the
    single-device chunked scan on 8 forced host devices."""
    from repro.kernels.sharded import hybrid_sharded, plan_kernel_sharding
    from repro.launch.mesh import make_test_mesh
    cfgm = _SHARD_MODES[mode]
    rng = np.random.default_rng(31)
    b, n, d, dv = 2, 32, 4, 8
    q, k, v = make_qkv(rng, b, cfgm["hq"], cfgm["hkv"], n, d, dv,
                       dtype=np.float64, normalized=True)
    w, cs = 8, 16
    cot = jnp.asarray(rng.normal(size=(b, cfgm["hq"], n, dv)), jnp.float64)

    o_ref = hybrid_causal_chunked(q, k, v, window=w, chunk_size=cs)
    g_ref = jax.grad(
        lambda q, k, v: jnp.sum(hybrid_causal_chunked(
            q, k, v, window=w, chunk_size=cs) * cot),
        argnums=(0, 1, 2))(q, k, v)

    mesh = make_test_mesh(cfgm["mesh"], ("data", "model"))
    with mesh:
        plan = plan_kernel_sharding(mesh, batch=b, hq=cfgm["hq"],
                                    hkv=cfgm["hkv"], dv=dv)
        assert plan is not None and plan.mode == mode, plan
        o_sh = hybrid_sharded(q, k, v, p=2, window=w, chunk_size=cs,
                              denom_eps=1e-6, plan=plan)
        g_sh = jax.grad(
            lambda q, k, v: jnp.sum(hybrid_sharded(
                q, k, v, p=2, window=w, chunk_size=cs, denom_eps=1e-6,
                plan=plan) * cot),
            argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_ref),
                               rtol=1e-10, atol=1e-10)
    for name, a, b_ in zip("qkv", g_sh, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-9, atol=1e-9, err_msg=name)
