"""Pallas kernels vs pure-jnp oracle, interpret mode (same code Mosaic would
compile on TPU), swept over shapes / dtypes / p / GQA group sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_fastmax_state
from repro.core.ref import normalize_qk
from repro.kernels.ops import (fastmax, fastmax_decode,
                               fastmax_prefill_kernel)
from repro.kernels.ref import fastmax_decode_ref, fastmax_ref

jax.config.update("jax_enable_x64", True)

pytestmark = pytest.mark.kernels


def mk(rng, b, hq, hkv, n, d, dv, dtype):
    q = normalize_qk(jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype))
    k = normalize_qk(jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype))
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


SHAPES = [
    (1, 2, 1, 32, 8, 8),     # GQA g=2
    (2, 4, 2, 100, 16, 16),  # padding (100 -> 112 at cs=16)
    (1, 8, 2, 64, 8, 8),     # g=4
    (1, 4, 4, 48, 4, 4),     # MHA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("p", [1, 2])
@pytest.mark.parametrize("causal", [False, True])
def test_kernel_matches_oracle_f64(shape, p, causal):
    rng = np.random.default_rng(hash((shape, p, causal)) % 2**31)
    q, k, v = mk(rng, *shape, jnp.float64)
    ref = fastmax_ref(q, k, v, p=p, causal=causal)
    out = fastmax(q, k, v, p=p, causal=causal, chunk_size=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-3),
                                       (jnp.bfloat16, 1e-1)])
def test_kernel_low_precision(dtype, tol):
    """fp32/bf16 inputs accumulate in fp32 — p=2 only (safe denominator)."""
    rng = np.random.default_rng(11)
    q, k, v = mk(rng, 1, 4, 2, 64, 8, 8, dtype)
    ref = fastmax_ref(q.astype(jnp.float64), k.astype(jnp.float64),
                      v.astype(jnp.float64), p=2, causal=True)
    out = fastmax(q, k, v, p=2, causal=True, chunk_size=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float64), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("p", [1, 2])
def test_decode_kernel_stream(p):
    rng = np.random.default_rng(12)
    B, Hq, Hkv, D, Dv = 2, 4, 2, 8, 8
    state = tuple(jax.tree.map(lambda x: x.astype(jnp.float64),
                               init_fastmax_state(B, Hkv, D, Dv, p=p)))
    for _ in range(4):
        q, k, v = mk(rng, B, Hq, Hkv, 1, D, Dv, jnp.float64)
        o_ref, st_ref = fastmax_decode_ref(q, k, v, state, p=p)
        o, st = fastmax_decode(q, k, v, state, p=p, interpret=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=1e-9, atol=1e-9)
        for a, b in zip(st, st_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-9, atol=1e-9)
        state = st


def test_kernel_gradient_matches_chunked():
    """Kernel fwd pairs with the §2.5 reversible backward."""
    import repro.core.fastmax as fm
    rng = np.random.default_rng(13)
    q, k, v = mk(rng, 1, 2, 1, 40, 8, 8, jnp.float64)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=2, causal=True,
                                       chunk_size=16, interpret=True)))

    def loss_j(q, k, v):
        return jnp.sum(jnp.sin(fm.fastmax_causal_chunked(
            q, k, v, p=2, chunk_size=16, custom_grad=False)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss_j, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("shape", [(1, 2, 1, 40, 8, 8),   # GQA g=2
                                   (1, 4, 2, 33, 8, 8),   # padding 33->48
                                   (1, 8, 2, 64, 8, 16)])  # g=4, Dv != D
@pytest.mark.parametrize("p", [1, 2])
def test_pallas_bwd_matches_jnp_bwd_f64(shape, p):
    """Fused Pallas backward == jnp §2.5 chunked reverse scan (the oracle
    it replaces on the hot path)."""
    import repro.core.fastmax as fm
    rng = np.random.default_rng(hash((shape, p)) % 2**31)
    q, k, v = mk(rng, *shape, jnp.float64)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=p, causal=True,
                                       chunk_size=16, interpret=True)))

    def loss_j(q, k, v):
        return jnp.sum(jnp.sin(fm.fastmax_causal_chunked(
            q, k, v, p=p, chunk_size=16, custom_grad=True)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss_j, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("p", [1, 2])
def test_noncausal_kernel_grads_match_jnp(p):
    """The noncausal kernel op is differentiable: its custom_vjp pairs the
    two-phase Pallas forward with autodiff of the jnp moment path (encoder
    attention trains through the kernel route, no forward reroute)."""
    import repro.core.fastmax as fm
    rng = np.random.default_rng(17 + p)
    q, k, v = mk(rng, 1, 4, 2, 33, 8, 8, jnp.float64)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=p, causal=False,
                                       chunk_size=16, interpret=True)))

    def loss_j(q, k, v):
        return jnp.sum(jnp.sin(fm.fastmax_noncausal(q, k, v, p=p,
                                                    chunk_size=16)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss_j, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("p", [1, 2])
def test_pallas_bwd_low_precision_vs_oracle_autodiff(dtype, tol, p):
    """Low-precision inputs, fp32 accumulation: Pallas backward vs plain
    autodiff through the chunked scan, both evaluated on the same inputs.
    The 1e-5 f32 rel-err bound is the PR acceptance criterion."""
    import repro.core.fastmax as fm
    rng = np.random.default_rng(17 + p)
    q, k, v = mk(rng, 1, 4, 2, 48, 8, 8, dtype)

    def loss_k(q, k, v):
        return jnp.sum(fastmax(q, k, v, p=p, causal=True, chunk_size=16,
                               interpret=True))

    def loss_o(q, k, v):
        return jnp.sum(fm.fastmax_causal_chunked(
            q, k, v, p=p, chunk_size=16, custom_grad=False))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    go = jax.grad(loss_o, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, go):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)
        assert rel <= tol, f"rel err {rel} > {tol}"


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_blocked_bwd_128x128_parity(monkeypatch, dtype, tol):
    """The tentpole shape: D = Dv = 128, p = 2, GQA. The auto-picked Dv
    carry block is < Dv (nb = 2 — the blocked schedule, two [D², 64]
    scratch tuples instead of two [D², 128]), and the blocked fused
    backward matches the jnp §2.5 reverse-scan oracle on the SAME
    kernel-emitted residual."""
    from repro.kernels import ops
    from repro.kernels.tiling import BWD_BLK_BUDGET, pick_blk

    d = dv = 128
    assert pick_blk(d, dv, BWD_BLK_BUDGET) < dv  # blocked path exercised
    rng = np.random.default_rng(41)
    q, k, v = mk(rng, 1, 2, 1, 64, d, dv, dtype)
    do = jnp.asarray(rng.normal(size=(1, 2, 64, dv)), dtype)
    _, res = ops._fc_fwd(q, k, v, 2, 32, 1e-6, True, None, None)
    assert ops.use_pallas_bwd()
    g_pallas = ops._fc_bwd(2, 32, 1e-6, True, None, None, res, do)
    monkeypatch.setenv("REPRO_FASTMAX_BWD", "jnp")
    g_jnp = ops._fc_bwd(2, 32, 1e-6, True, None, None, res, do)
    for a, b in zip(g_pallas, g_jnp):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)
        assert rel <= tol, f"rel err {rel} > {tol}"


@pytest.mark.parametrize("p", [1, 2])
def test_forced_blocking_matches_unblocked(p):
    """Forcing small Dv carry blocks (nb in {2, 4, 8}) reproduces the
    unblocked (blk = Dv) forward outputs, emitted carry, and backward
    cotangents — the additive-over-Dv decomposition is exact, f64."""
    from repro.kernels.fastmax_causal import fastmax_causal_pallas
    from repro.kernels.fastmax_causal_bwd import fastmax_causal_bwd_pallas

    rng = np.random.default_rng(43 + p)
    b, hq, hkv, n, d, dv = 1, 4, 2, 33, 8, 16
    q, k, v = mk(rng, b, hq, hkv, n, d, dv, jnp.float64)
    do = jnp.asarray(rng.normal(size=(b, hq, n, dv)), jnp.float64)
    o_ref, st_ref = fastmax_causal_pallas(
        q, k, v, p=p, chunk_size=16, interpret=True, return_state=True,
        blk=dv)
    g_ref = fastmax_causal_bwd_pallas(
        q, k, v, tuple(st_ref), do, p=p, chunk_size=16, interpret=True,
        blk=dv)
    for blk in (8, 4, 2):
        o_b, st_b = fastmax_causal_pallas(
            q, k, v, p=p, chunk_size=16, interpret=True, return_state=True,
            blk=blk)
        np.testing.assert_allclose(np.asarray(o_b), np.asarray(o_ref),
                                   rtol=1e-12, atol=1e-12)
        for a, bb in zip(st_b, st_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       rtol=1e-12, atol=1e-12)
        g_b = fastmax_causal_bwd_pallas(
            q, k, v, tuple(st_ref), do, p=p, chunk_size=16, interpret=True,
            blk=blk)
        for a, bb in zip(g_b, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       rtol=1e-11, atol=1e-12)


def test_jnp_bwd_oracle_stays_wired(monkeypatch):
    """REPRO_FASTMAX_BWD=jnp reroutes the custom_vjp backward rule to the
    jnp §2.5 reverse scan (the interpret-mode oracle escape hatch); both
    rules produce the same cotangents from the same kernel-emitted
    residual."""
    from repro.kernels import ops
    rng = np.random.default_rng(21)
    q, k, v = mk(rng, 1, 2, 1, 32, 8, 8, jnp.float64)
    do = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float64)
    _, res = ops._fc_fwd(q, k, v, 2, 16, 1e-6, True, None, None)
    assert ops.use_pallas_bwd()
    g_pallas = ops._fc_bwd(2, 16, 1e-6, True, None, None, res, do)
    monkeypatch.setenv("REPRO_FASTMAX_BWD", "jnp")
    assert not ops.use_pallas_bwd()
    g_jnp = ops._fc_bwd(2, 16, 1e-6, True, None, None, res, do)
    for a, b in zip(g_pallas, g_jnp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("shape", [(1, 2, 1, 32, 8, 8),
                                   (2, 4, 2, 100, 16, 16)])
@pytest.mark.parametrize("p", [1, 2])
def test_forward_emits_final_state(shape, p):
    """return_state=True: the forward kernel's own carry == full-sequence
    moments (the prefill→decode handoff and the backward residual)."""
    from repro.core.fastmax import compute_moments
    rng = np.random.default_rng(hash((shape, p, "st")) % 2**31)
    q, k, v = mk(rng, *shape, jnp.float64)
    o, state = fastmax_prefill_kernel(q, k, v, p=p, chunk_size=16, interpret=True)
    ref_o = fastmax_ref(q, k, v, p=p, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref_o),
                               rtol=1e-9, atol=1e-9)
    mom = compute_moments(k, v, p=p)
    for got, want in zip(state, mom):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("p", [1, 2])
def test_decode_long_horizon_kernel_vs_jnp(p):
    """Prefill + 256 decode steps: the kernel-carried state stays in
    lockstep with the jnp moment step (no drift over a long horizon)."""
    from repro.core.fastmax import Moments
    from repro.core.decode_state import fastmax_decode_step
    rng = np.random.default_rng(31 + p)
    B, Hq, Hkv, N, D, Dv = 1, 2, 1, 16, 4, 4
    q, k, v = mk(rng, B, Hq, Hkv, N, D, Dv, jnp.float64)
    _, state_k = fastmax_prefill_kernel(q, k, v, p=p, chunk_size=8, interpret=True)
    state_j = Moments(*state_k)
    st_k = tuple(state_k)
    for i in range(256):
        q1, k1, v1 = mk(rng, B, Hq, Hkv, 1, D, Dv, jnp.float64)
        o_k, st_k = fastmax_decode(q1, k1, v1, st_k, p=p, interpret=True)
        o_j, state_j = fastmax_decode_step(state_j, q1, k1, v1, p=p,
                                           normalize=False)
        if i % 64 == 63 or i == 255:
            np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_j),
                                       rtol=1e-8, atol=1e-9)
    for a, b in zip(st_k, state_j):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-8, atol=1e-9)


def test_kernel_vs_oracle_decode_after_prefill_consistency():
    """Moment state built by full-sequence moments == kernel decode stream."""
    from repro.core.fastmax import compute_moments
    rng = np.random.default_rng(14)
    B, Hq, Hkv, N, D, Dv = 1, 2, 2, 24, 8, 8
    q, k, v = mk(rng, B, Hq, Hkv, N, D, Dv, jnp.float64)
    mom = compute_moments(k[:, :, :N - 1], v[:, :, :N - 1], p=2)
    o_k, _ = fastmax_decode(q[:, :, N - 1:], k[:, :, N - 1:], v[:, :, N - 1:],
                            tuple(mom), p=2, interpret=True)
    full = fastmax_ref(q, k, v, p=2, causal=True)
    np.testing.assert_allclose(np.asarray(o_k[:, :, 0]),
                               np.asarray(full[:, :, N - 1]),
                               rtol=1e-9, atol=1e-9)
