"""Pallas kernels vs pure-jnp oracle, interpret mode (same code Mosaic would
compile on TPU), swept over shapes / dtypes / p / GQA group sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_fastmax_state
from repro.core.ref import normalize_qk
from repro.kernels.ops import fastmax, fastmax_decode
from repro.kernels.ref import fastmax_decode_ref, fastmax_ref

jax.config.update("jax_enable_x64", True)


def mk(rng, b, hq, hkv, n, d, dv, dtype):
    q = normalize_qk(jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype))
    k = normalize_qk(jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype))
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


SHAPES = [
    (1, 2, 1, 32, 8, 8),     # GQA g=2
    (2, 4, 2, 100, 16, 16),  # padding (100 -> 112 at cs=16)
    (1, 8, 2, 64, 8, 8),     # g=4
    (1, 4, 4, 48, 4, 4),     # MHA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("p", [1, 2])
@pytest.mark.parametrize("causal", [False, True])
def test_kernel_matches_oracle_f64(shape, p, causal):
    rng = np.random.default_rng(hash((shape, p, causal)) % 2**31)
    q, k, v = mk(rng, *shape, jnp.float64)
    ref = fastmax_ref(q, k, v, p=p, causal=causal)
    out = fastmax(q, k, v, p=p, causal=causal, chunk_size=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-3),
                                       (jnp.bfloat16, 1e-1)])
def test_kernel_low_precision(dtype, tol):
    """fp32/bf16 inputs accumulate in fp32 — p=2 only (safe denominator)."""
    rng = np.random.default_rng(11)
    q, k, v = mk(rng, 1, 4, 2, 64, 8, 8, dtype)
    ref = fastmax_ref(q.astype(jnp.float64), k.astype(jnp.float64),
                      v.astype(jnp.float64), p=2, causal=True)
    out = fastmax(q, k, v, p=2, causal=True, chunk_size=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float64), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("p", [1, 2])
def test_decode_kernel_stream(p):
    rng = np.random.default_rng(12)
    B, Hq, Hkv, D, Dv = 2, 4, 2, 8, 8
    state = tuple(jax.tree.map(lambda x: x.astype(jnp.float64),
                               init_fastmax_state(B, Hkv, D, Dv, p=p)))
    for _ in range(4):
        q, k, v = mk(rng, B, Hq, Hkv, 1, D, Dv, jnp.float64)
        o_ref, st_ref = fastmax_decode_ref(q, k, v, state, p=p)
        o, st = fastmax_decode(q, k, v, state, p=p, interpret=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=1e-9, atol=1e-9)
        for a, b in zip(st, st_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-9, atol=1e-9)
        state = st


def test_kernel_gradient_matches_chunked():
    """Kernel fwd pairs with the §2.5 reversible backward."""
    import repro.core.fastmax as fm
    rng = np.random.default_rng(13)
    q, k, v = mk(rng, 1, 2, 1, 40, 8, 8, jnp.float64)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(fastmax(q, k, v, p=2, causal=True,
                                       chunk_size=16, interpret=True)))

    def loss_j(q, k, v):
        return jnp.sum(jnp.sin(fm.fastmax_causal_chunked(
            q, k, v, p=2, chunk_size=16, custom_grad=False)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss_j, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-8, atol=1e-10)


def test_kernel_vs_oracle_decode_after_prefill_consistency():
    """Moment state built by full-sequence moments == kernel decode stream."""
    from repro.core.fastmax import compute_moments
    rng = np.random.default_rng(14)
    B, Hq, Hkv, N, D, Dv = 1, 2, 2, 24, 8, 8
    q, k, v = mk(rng, B, Hq, Hkv, N, D, Dv, jnp.float64)
    mom = compute_moments(k[:, :, :N - 1], v[:, :, :N - 1], p=2)
    o_k, _ = fastmax_decode(q[:, :, N - 1:], k[:, :, N - 1:], v[:, :, N - 1:],
                            tuple(mom), p=2, interpret=True)
    full = fastmax_ref(q, k, v, p=2, causal=True)
    np.testing.assert_allclose(np.asarray(o_k[:, :, 0]),
                               np.asarray(full[:, :, N - 1]),
                               rtol=1e-9, atol=1e-9)
