"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    """Full driver: fastmax model learns the synthetic stream."""
    params = train_mod.main([
        "--arch", "qwen3-1.7b", "--smoke", "--steps", "60", "--batch", "8",
        "--seq", "64", "--ckpt-dir", str(tmp_path), "--log-every", "50",
        "--lr", "3e-3",
    ])
    assert params is not None


@pytest.mark.slow
def test_train_resume_continues(tmp_path, capsys):
    train_mod.main(["--arch", "granite-20b", "--smoke", "--steps", "8",
                    "--batch", "4", "--seq", "32",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    train_mod.main(["--arch", "granite-20b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "32",
                    "--ckpt-dir", str(tmp_path), "--resume"])
    out = capsys.readouterr().out
    assert "resumed from step" in out


@pytest.mark.slow
def test_serve_generates(capsys):
    serve_mod.main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
                    "--prompt-len", "12", "--gen", "6"])
    out = capsys.readouterr().out
    assert "generated (2, 6)" in out


@pytest.mark.slow
def test_fastmax_vs_softmax_learning_parity():
    """Paper's core claim (Table 1 / Fig 6): fastmax is as expressive —
    train tiny models on the same stream, final losses within 25%."""
    losses = {}
    for backend in ("fastmax2", "softmax"):
        import dataclasses
        import jax
        from repro.attention import AttentionSpec
        from repro.configs import get_smoke_config
        from repro.data import SyntheticLM
        from repro.launch.steps import make_train_step, pick_optimizer
        from repro.models import init_model

        cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                                  attn=AttentionSpec.parse(backend))
        params, _ = init_model(jax.random.PRNGKey(1), cfg)
        _, opt = pick_optimizer(cfg, 1e6, lr=3e-3, total_steps=80)
        opt_state = opt[0](params)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        data = SyntheticLM(cfg.vocab_size, 64, seed=0)
        last = []
        for s in range(80):
            batch = jax.tree.map(jnp.asarray, data.batch(s, 8))
            params, opt_state, m = step_fn(params, opt_state, batch)
            last.append(float(m["loss"]))
        losses[backend] = np.mean(last[-10:])
    assert losses["fastmax2"] < 1.25 * losses["softmax"], losses
    # and both learned something
    assert losses["fastmax2"] < 6.0
