"""Per-arch smoke tests (reduced same-family configs) + model-level
behaviour: one fwd/train step on CPU asserting shapes + no NaNs, decode
consistency, backend swap."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttentionSpec
from repro.configs import all_arch_ids, get_smoke_config
from repro.models import (decode_step, init_decode_state, init_model,
                          model_loss)
from repro.models.transformer import forward_lm, lm_prefill


def _batch(cfg, rng, b=2, n=32):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, n)), jnp.int32)}
    if cfg.encoder_layers > 0:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_train_step(arch):
    rng = np.random.default_rng(0)
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model_loss(p, batch, cfg), has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), arch
    assert float(gnorm) > 0.0, arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_decode_step(arch):
    rng = np.random.default_rng(1)
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    b = 2
    st = init_decode_state(cfg, b, 16)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b,)), jnp.int32)
    enc_out = None
    p = params
    if cfg.encoder_layers > 0:
        from repro.models.encdec import encode
        frames = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        enc_out = encode(params, frames, cfg)
        p = params["decoder"]
    logits, st2 = decode_step(p, st, tok, cfg, position=0, enc_out=enc_out)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # state must actually change
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))) > 0
        for a, b_ in zip(jax.tree.leaves(st), jax.tree.leaves(st2))
        if hasattr(a, "shape") and a.size)
    assert changed, arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-32b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "deepseek-v2-236b"])
def test_prefill_decode_equals_forward(arch):
    """serve path == train path: prefill(prompt)+decode(last) must equal the
    full causal forward at the last position. MoE archs: capacity_factor
    large enough that training drops nothing (inference never drops)."""
    rng = np.random.default_rng(2)
    cfg = get_smoke_config(arch, capacity_factor=8.0)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 17)), jnp.int32)
    logits_full, _ = forward_lm(params, toks, cfg)
    st = init_decode_state(cfg, 2, 32)
    _, st = lm_prefill(params, toks[:, :-1], cfg, st)
    logits_dec, _ = decode_step(params, st, toks[:, -1], cfg,
                                position=toks.shape[1] - 1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_backend_swap_softmax_vs_fastmax():
    """FAST is a drop-in: same params, both backends produce finite,
    DIFFERENT outputs (different attention metrics)."""
    rng = np.random.default_rng(3)
    cfg = get_smoke_config("qwen2.5-32b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    outs = {}
    for backend in ("fastmax2", "fastmax1", "softmax"):
        c = dataclasses.replace(cfg, attn=AttentionSpec.parse(backend))
        logits, _ = forward_lm(params, toks, c)
        assert bool(jnp.all(jnp.isfinite(logits))), backend
        outs[backend] = logits
    assert float(jnp.max(jnp.abs(outs["fastmax2"] - outs["softmax"]))) > 1e-4
    assert float(jnp.max(jnp.abs(outs["fastmax2"] - outs["fastmax1"]))) > 1e-5


def test_kernel_impl_matches_chunked_in_model():
    """impl='kernel' (interpret on CPU) == impl='chunked'."""
    rng = np.random.default_rng(4)
    cfg = get_smoke_config("granite-20b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 24)), jnp.int32)
    l1, _ = forward_lm(params, toks, cfg)
    cfg_k = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, impl="kernel"))
    l2, _ = forward_lm(params, toks, cfg_k)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_whisper_cross_attention_uses_encoder():
    rng = np.random.default_rng(5)
    cfg = get_smoke_config("whisper-small")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, rng)
    loss1, _ = model_loss(params, b, cfg)
    b2 = dict(b)
    # content perturbation (a constant shift would be removed by LayerNorm)
    b2["frames"] = b["frames"] + jnp.asarray(
        rng.normal(size=b["frames"].shape), jnp.float32)
    loss2, _ = model_loss(params, b2, cfg)
    assert abs(float(loss1) - float(loss2)) > 1e-6
