"""Chaos tier for the serving engine (`faults` marker; `make test-faults`).

The contract under test: injected faults — NaN into a slot's state, a
user callback that raises, burst overload, expired deadlines, mid-stream
cancellation, wedged host lanes — must fail ONLY the targeted request,
with the correct `RequestStatus` and a diagnostic, while every unaffected
request produces tokens BYTE-IDENTICAL to an undisturbed run. The engine
itself never crashes; it degrades (reject/shed) or raises the structured
`EngineStalled` with a snapshot when it genuinely cannot make progress.

All faults are scheduled by engine tick (`serve/faults.py`), so every
scenario here is exactly reproducible.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import init_model
from repro.serve import (EngineOverloaded, EngineStalled, FaultInjector,
                         PrefixCache, RequestStatus, ServeEngine)
from repro.serve.faults import burst, exploding_callback, poison_slot

pytestmark = pytest.mark.faults


@pytest.fixture(scope="module")
def fm():
    """One (cfg, params) pair shared across the tier (fastmax2-chunked on
    the GQA smoke config — the moment-state backend the quarantine guard
    exists for)."""
    cfg = get_smoke_config("qwen3-1.7b")
    cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(
        "fastmax2-chunked"))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ref(params, cfg, prompt, gen, max_len):
    return np.asarray(generate(params, cfg, jnp.asarray(prompt[None]), gen,
                               max_len=max_len))[0]


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


# ---------------------------------------------------------------------------
# callback isolation (satellite: raise on the 3rd token must not kill pool)
# ---------------------------------------------------------------------------


def test_callback_raising_on_third_token_fails_only_its_request(fm):
    cfg, params = fm
    victim, bystander = _prompts(cfg, (14, 11), seed=1)
    G = 6
    ref = _ref(params, cfg, bystander, G, 64)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    rv = eng.submit(victim, G, callback=exploding_callback(3))
    rb = eng.submit(bystander, G)
    outs = eng.run()                       # must not raise

    assert eng.status(rv) is RequestStatus.FAILED
    fin_v = next(f for f in eng.history if f.rid == rv)
    assert "callback raised" in fin_v.error
    assert len(fin_v.tokens) == 3          # the 3rd token was produced
    np.testing.assert_array_equal(outs[rb], ref)   # bystander untouched
    assert eng.status(rb) is RequestStatus.FINISHED

    # the freed slot serves the next tenant correctly
    late = _prompts(cfg, (9,), seed=2)[0]
    rl = eng.submit(late, G)
    outs = eng.run()
    np.testing.assert_array_equal(outs[rl], _ref(params, cfg, late, G, 64))


# ---------------------------------------------------------------------------
# submit() validation (satellite: context bound, eos_id type/sign)
# ---------------------------------------------------------------------------


def test_submit_rejects_overlong_prompt_and_bad_eos(fm):
    cfg, params = fm
    eng = ServeEngine(params, cfg, max_slots=1, max_len=32)
    long_prompt = np.zeros(40, np.int32)
    with pytest.raises(ValueError, match="exceeds the model context"):
        eng.submit(long_prompt, 1)
    ok_prompt = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="eos_id must be non-negative"):
        eng.submit(ok_prompt, 4, eos_id=-1)
    with pytest.raises(ValueError, match="eos_id must be an integer"):
        eng.submit(ok_prompt, 4, eos_id=1.5)
    with pytest.raises(ValueError, match="eos_id must be an integer"):
        eng.submit(ok_prompt, 4, eos_id=True)   # bool is always a bug
    with pytest.raises(ValueError, match="ttft_deadline must be >= 0"):
        eng.submit(ok_prompt, 4, ttft_deadline=-1.0)
    assert eng.pending == 0                # nothing was enqueued
    # the engine-level default is validated at construction too
    with pytest.raises(ValueError, match="eos_id must be non-negative"):
        ServeEngine(params, cfg, max_slots=1, max_len=32, eos_id=-7)


# ---------------------------------------------------------------------------
# backpressure: bounded queue + load shedding
# ---------------------------------------------------------------------------


def test_burst_overload_rejects_then_recovers(fm):
    cfg, params = fm
    prompts = _prompts(cfg, (10, 12, 14, 9, 11, 13), seed=3)
    G = 3
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, max_queue=2)
    rids, rejected = burst(eng, prompts, G)
    assert len(rids) == 2 and rejected == 4
    assert eng.stats()["rejected"] == 4
    outs = eng.run()                       # admitted requests complete
    assert all(eng.status(r) is RequestStatus.FINISHED for r in rids)
    for rid, p in zip(rids, prompts[:2]):
        np.testing.assert_array_equal(outs[rid], _ref(params, cfg, p, G, 64))
    # backpressure clears once the queue drains
    r_new = eng.submit(prompts[2], G)
    outs = eng.run()
    np.testing.assert_array_equal(outs[r_new],
                                  _ref(params, cfg, prompts[2], G, 64))


def test_queued_token_budget_rejects(fm):
    cfg, params = fm
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64,
                      max_queue_tokens=20)
    eng.submit(np.zeros(12, np.int32), 1)
    with pytest.raises(EngineOverloaded, match="token budget"):
        eng.submit(np.zeros(12, np.int32), 1)


def test_shed_newest_largest_under_sustained_saturation(fm):
    cfg, params = fm
    # slot 0 is held by a long-running request; the queue sits full for
    # `shed_after` ticks -> the newest/largest waiter is shed with a
    # structured REJECTED record, and the survivors still complete
    prompts = _prompts(cfg, (12, 8, 9, 30), seed=4)   # [3] is the victim
    G = 8
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, max_queue=3,
                      shed_after=2)
    r_hold = eng.submit(prompts[0], G)
    eng.step()                             # r_hold takes the slot
    queued = [eng.submit(p, 2) for p in prompts[1:]]
    victim = queued[-1]                    # largest prompt, newest
    eng.step()                             # saturation tick 1
    fins = eng.step()                      # tick 2: shed kicks in
    shed = [f for f in fins if f.status is RequestStatus.REJECTED]
    assert [f.rid for f in shed] == [victim]
    assert "shed after" in shed[0].error
    assert eng.stats()["shed"] == 1
    outs = eng.run()
    for rid in [r_hold] + queued[:-1]:
        assert eng.status(rid) is RequestStatus.FINISHED
        assert rid in outs


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_ttft_deadline_expires_in_queue(fm):
    cfg, params = fm
    p_victim, p_ok = _prompts(cfg, (10, 13), seed=5)
    G = 4
    ref = _ref(params, cfg, p_ok, G, 64)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    rv = eng.submit(p_victim, G, ttft_deadline=0.0)
    rk = eng.submit(p_ok, G)
    outs = eng.run()
    assert eng.status(rv) is RequestStatus.TIMED_OUT
    fin = next(f for f in eng.history if f.rid == rv)
    assert "RequestTimeout" in fin.error and fin.ttft is None
    assert len(fin.tokens) == 0
    np.testing.assert_array_equal(outs[rk], ref)


def test_total_deadline_expires_mid_decode(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (11,), seed=6)
    G = 12
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    rid = eng.submit(prompt, G)
    eng.step()                             # prefill completes, token #1
    eng.step()                             # a decode token
    assert eng.status(rid) is RequestStatus.DECODE
    eng._req[rid].deadline = 1e-9          # expire it mid-flight
    fins = eng.step()
    assert [f.rid for f in fins] == [rid]
    fin = fins[0]
    assert fin.status is RequestStatus.TIMED_OUT
    assert 0 < len(fin.tokens) < G and fin.ttft is not None
    assert eng.stats()["timed_out"] == 1
    assert eng.stats()["slots_occupied"] == 0   # slot was freed


# ---------------------------------------------------------------------------
# non-finite quarantine + lockstep-parity isolation (satellite)
# ---------------------------------------------------------------------------


def test_nan_quarantine_isolates_and_matches_undisturbed_run(fm):
    """Poison one slot mid-decode: that request FAILs with a quarantine
    diagnostic, every other request's tokens are byte-identical to an
    undisturbed engine run, and the quarantined slot serves the next
    tenant exactly."""
    cfg, params = fm
    others = _prompts(cfg, (12, 9, 14), seed=7)
    (victim,) = _prompts(cfg, (10,), seed=8)
    G = 8

    clean = ServeEngine(params, cfg, max_slots=4, max_len=64, chunk=16)
    rids_a = [clean.submit(p, G) for p in others]
    outs_a = clean.run()

    inj = FaultInjector().nan_into_slot(tick=6, slot=3)
    eng = ServeEngine(params, cfg, max_slots=4, max_len=64, chunk=16,
                      faults=inj)
    rids_b = [eng.submit(p, G) for p in others]
    rv = eng.submit(victim, G)             # fcfs: victim lands in slot 3
    outs_b = eng.run()                     # never crashes

    assert inj.log == [(6, "nan_into_slot(3)")]
    assert eng.status(rv) is RequestStatus.FAILED
    fin_v = next(f for f in eng.history if f.rid == rv)
    assert "SlotQuarantined" in fin_v.error
    assert eng.stats()["quarantined"] == 1
    for ra, rb in zip(rids_a, rids_b):
        np.testing.assert_array_equal(outs_b[rb], outs_a[ra])
        assert eng.status(rb) is RequestStatus.FINISHED

    # the re-initialized slot decodes the next tenant bit-exactly
    (late,) = _prompts(cfg, (13,), seed=9)
    rl = eng.submit(late, G)
    outs = eng.run()
    np.testing.assert_array_equal(outs[rl], _ref(params, cfg, late, G, 64))


def test_nan_during_prefill_is_quarantined(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (24,), seed=10)   # 3 chunks at chunk=8
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, chunk=8,
                      faults=FaultInjector().nan_into_slot(tick=2, slot=0))
    rid = eng.submit(prompt, 4)
    eng.run()
    assert eng.status(rid) is RequestStatus.FAILED
    fin = next(f for f in eng.history if f.rid == rid)
    assert "prefill" in fin.error and len(fin.tokens) == 0


def test_quarantine_purges_poisoned_prefix_snapshots(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (20,), seed=11)   # chunk boundary at 8, 16
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, chunk=8,
                      prefix_cache_bytes=1 << 30,
                      faults=FaultInjector().nan_into_slot(tick=2, slot=0))
    rid = eng.submit(prompt, 4)
    eng.run()
    assert eng.status(rid) is RequestStatus.FAILED
    # the tick-1 snapshot (after 8 tokens) must NOT survive to seed a
    # same-prefix request with poisoned state
    assert eng.prefix_cache.lookup(prompt) == (0, None) or \
        eng.prefix_cache.lookup(prompt)[0] == 0


def test_deep_state_check_catches_latent_nan(fm, monkeypatch):
    """REPRO_SERVE_CHECK_STATE=1: a slot poisoned while it is NOT emitting
    (another slot's prefill turn) is caught by the deep leaf check the
    same tick, before its poison can reach logits or the prefix cache."""
    monkeypatch.setenv("REPRO_SERVE_CHECK_STATE", "1")
    cfg, params = fm
    p0, p1 = _prompts(cfg, (24, 24), seed=12)   # 3 chunks each at chunk=8
    # tick 1 prefills slot 0, tick 2 slot 1, tick 3 slot 0 again: poison
    # slot 1 at tick 3, when only slot 0 emits logits
    inj = FaultInjector().nan_into_slot(tick=3, slot=1)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64, chunk=8,
                      faults=inj)
    r0 = eng.submit(p0, 3)
    r1 = eng.submit(p1, 3)
    outs = eng.run()
    assert eng.status(r1) is RequestStatus.FAILED
    fin = next(f for f in eng.history if f.rid == r1)
    assert "deep check" in fin.error
    np.testing.assert_array_equal(outs[r0], _ref(params, cfg, p0, 3, 64))


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_isolates_and_matches_undisturbed_run(fm):
    cfg, params = fm
    others = _prompts(cfg, (12, 9), seed=13)
    (victim,) = _prompts(cfg, (10,), seed=14)
    G = 8

    clean = ServeEngine(params, cfg, max_slots=3, max_len=64, chunk=16)
    rids_a = [clean.submit(p, G) for p in others]
    outs_a = clean.run()

    eng = ServeEngine(params, cfg, max_slots=3, max_len=64, chunk=16)
    rids_b = [eng.submit(p, G) for p in others]
    rv = eng.submit(victim, G)
    eng.faults = FaultInjector().cancel_at(tick=6, rid=rv)
    outs_b = eng.run()

    assert eng.status(rv) is RequestStatus.CANCELLED
    fin_v = next(f for f in eng.history if f.rid == rv)
    assert "mid-decode" in fin_v.error and 0 < len(fin_v.tokens) < G
    assert eng.stats()["cancelled"] == 1
    for ra, rb in zip(rids_a, rids_b):
        np.testing.assert_array_equal(outs_b[rb], outs_a[ra])


def test_cancel_queued_and_unknown(fm):
    cfg, params = fm
    p0, p1 = _prompts(cfg, (10, 11), seed=15)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    r0 = eng.submit(p0, 3)
    r1 = eng.submit(p1, 3)                  # stays queued behind r0
    assert eng.cancel(r1) is True
    assert eng.status(r1) is RequestStatus.CANCELLED
    assert eng.cancel(r1) is False          # already terminal
    assert eng.cancel(999) is False         # unknown rid
    outs = eng.run()
    assert r1 not in outs and eng.status(r0) is RequestStatus.FINISHED


def test_cancel_mid_prefill_frees_slot(fm):
    cfg, params = fm
    (long_p,) = _prompts(cfg, (40,), seed=16)   # 5 chunks at chunk=8
    (short_p,) = _prompts(cfg, (9,), seed=17)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, chunk=8)
    rv = eng.submit(long_p, 4)
    eng.step()                              # one prefill chunk in
    assert eng.status(rv) is RequestStatus.PREFILL
    assert eng.cancel(rv) is True
    fin = next(f for f in eng.history if f.rid == rv)
    assert "mid-prefill" in fin.error and len(fin.tokens) == 0
    rs = eng.submit(short_p, 4)             # slot is immediately reusable
    outs = eng.run()
    np.testing.assert_array_equal(outs[rs],
                                  _ref(params, cfg, short_p, 4, 64))


# ---------------------------------------------------------------------------
# watchdog: stalls are structured failures, never silent spins
# ---------------------------------------------------------------------------


def test_run_raises_engine_stalled_at_max_ticks(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (10,), seed=18)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64)
    eng.submit(prompt, 8)                   # needs ~9 ticks
    with pytest.raises(EngineStalled, match="max_ticks=2") as ei:
        eng.run(max_ticks=2)
    snap = ei.value.snapshot
    assert snap is not None and snap["slots"][0]["rid"] is not None


def test_tick_budget_watchdog_trips_on_sustained_slow_ticks(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (10,), seed=19)
    inj = FaultInjector()
    for t in (2, 3, 4, 5, 6):
        inj.slow_tick(t, 0.05)
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64,
                      tick_budget_s=0.02, faults=inj)
    eng.submit(prompt, 32)
    with pytest.raises(EngineStalled, match="wall-clock budget") as ei:
        eng.run()
    assert ei.value.snapshot["tick_time"]["max_s"] >= 0.05


def test_no_progress_stall_detected(fm):
    cfg, params = fm
    (prompt,) = _prompts(cfg, (24,), seed=20)   # multi-chunk prefill

    def wedge(eng):
        # simulate a lost wakeup: the slot claims its prompt is done but
        # never went active — no prefill picked, no decode, queue empty
        eng.slots.position[0] = len(prompt)

    inj = FaultInjector().call(2, wedge, name="wedge")
    eng = ServeEngine(params, cfg, max_slots=1, max_len=64, chunk=8,
                      stall_ticks=5, faults=inj)
    eng.submit(prompt, 4)
    with pytest.raises(EngineStalled, match="no tick progress") as ei:
        eng.run()
    assert ei.value.snapshot["queue_depth"] == 0
    assert ei.value.snapshot["counters"]["admitted"] == 1


# ---------------------------------------------------------------------------
# prefix-cache invalidation (unit) + stats/lifecycle bookkeeping
# ---------------------------------------------------------------------------


def test_prefix_cache_invalidate_removes_only_that_prompts_prefixes():
    cache = PrefixCache(byte_budget=1 << 20, chunk=4)
    state = {"x": np.zeros(10, np.float32)}
    p = np.arange(12, dtype=np.int32)
    other = np.arange(100, 112, dtype=np.int32)
    cache.insert(p, 4, state)
    cache.insert(p, 8, state)
    cache.insert(other, 4, state)
    assert cache.invalidate(p) == 2
    assert len(cache) == 1 and cache.bytes == 40
    assert cache.lookup(p) == (0, None)
    assert cache.lookup(other)[0] == 4      # unrelated entry survives


def test_stats_and_lifecycle_bookkeeping(fm):
    cfg, params = fm
    p0, p1 = _prompts(cfg, (10, 12), seed=21)
    eng = ServeEngine(params, cfg, max_slots=2, max_len=64)
    r0 = eng.submit(p0, 3)
    assert eng.status(r0) is RequestStatus.QUEUED
    r1 = eng.submit(p1, 3)
    eng.run()
    st = eng.stats()
    assert st["admitted"] == 2 and st["finished"] == 2
    assert st["queue_depth"] == 0 and st["slots_occupied"] == 0
    assert st["slots_total"] == 2 and st["decode_tokens"] > 0
    for f in eng.history:
        assert f.ok and f.status is RequestStatus.FINISHED
        assert f.error is None and f.ttft is not None
    assert {eng.status(r0), eng.status(r1)} == {RequestStatus.FINISHED}


def test_poison_slot_touches_only_float_leaves(fm):
    cfg, params = fm
    from repro.serve.slots import SlotManager
    sm = SlotManager(cfg, max_slots=2, max_len=32)
    n = poison_slot(sm, 0)
    assert n > 0
    for leaf in jax.tree.leaves(sm.snapshot(0)):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isnan(arr).all()
        else:
            assert np.isfinite(arr.astype(np.float64)).all()
    # the neighbouring slot is untouched
    for leaf in jax.tree.leaves(sm.snapshot(1)):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert not np.isnan(arr).any()
