"""qwen2.5-32b [dense]: 64L d=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
GQA + QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        vocab_size=152064, d_model=5120, n_layers=64,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648,
        pattern=("attn:mlp",),
        qkv_bias=True, rope_theta=1e6,
        mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
