"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early fusion: VQ image tokens are ordinary vocab ids (frontend stubbed);
qk_norm per the Chameleon stability fix. [arXiv:2405.09818; unverified]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        vocab_size=65536, d_model=8192, n_layers=48,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016,
        pattern=("attn:mlp",),
        qk_norm=True, rope_theta=1e4,
        mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
