"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm + GQA, tied embeddings. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        vocab_size=151936, d_model=2048, n_layers=28,
        n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144,
        pattern=("attn:mlp",),
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
        mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
