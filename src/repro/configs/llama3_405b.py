"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        vocab_size=128256, d_model=16384, n_layers=126,
        n_heads=128, n_kv_heads=8, head_dim=128, d_ff=53248,
        pattern=("attn:mlp",),
        rope_theta=5e5, mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, n_heads=8, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
