"""xlstm-1.3b [ssm]: 48L d=2048 4H d_ff=0 vocab=50304. sLSTM + mLSTM blocks
(xLSTM[7:1] interleave). ATTENTION-FREE: FAST inapplicable (DESIGN.md
§Arch-applicability). [arXiv:2405.04517; unverified]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        vocab_size=50304, d_model=2048, n_layers=48,
        n_heads=4, n_kv_heads=4, head_dim=512, d_ff=0,
        pattern=("mlstm:none",) * 7 + ("slstm:none",),
        rope_theta=0.0, norm_type="rmsnorm", tie_embeddings=True,
        attn=AttentionSpec(family="fastmax", p=2),  # unused (no attention blocks)
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=32, n_layers=8, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
