"""deepseek-v2-236b [moe]: 60L d=5120 128H d_ff(expert)=1536 vocab=102400.
MLA kv_lora=512, MoE 2 shared + 160 routed top-6, first layer dense.
[arXiv:2405.04434; hf]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        vocab_size=102400, d_model=5120, n_layers=60,
        n_heads=128, n_kv_heads=128, head_dim=128, d_ff=12288,
        pattern=("attn:moe",), first_k_dense=1,
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        n_experts=160, moe_top_k=6, n_shared_experts=2, d_ff_expert=1536,
        rope_theta=1e4, mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=3, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, first_k_dense=1,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        n_experts=8, moe_top_k=2, n_shared_experts=1, d_ff_expert=32,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
