"""granite-20b [dense]: 52L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
llama-arch, code. [arXiv:2405.04324; hf]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        vocab_size=49152, d_model=6144, n_layers=52,
        n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576,
        pattern=("attn:mlp",),
        rope_theta=1e4, mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
