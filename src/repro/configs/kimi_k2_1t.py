"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) d_ff(expert)=2048
vocab=163840, MoE 384e top-8 (+1 shared), first layer dense.
Trillion-param MoE (paper-table). [arXiv:2501.kimi2; unverified]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        vocab_size=163840, d_model=7168, n_layers=61,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=18432,
        pattern=("attn:moe",), first_k_dense=1,
        n_experts=384, moe_top_k=8, n_shared_experts=1, d_ff_expert=2048,
        rope_theta=5e4, mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=3, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, first_k_dense=1,
        n_experts=8, moe_top_k=2, n_shared_experts=1, d_ff_expert=32,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
