"""Assigned-architecture registry: one module per arch + shape table.

Every config is exact per the assignment (10 archs x 4 shapes = 40 cells).
`get_config(name, **overrides)` returns the FULL config;
`get_smoke_config(name)` returns the reduced same-family config used by the
per-arch CPU smoke tests (full configs are only exercised via the dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import NamedTuple

from repro.models.transformer import ModelConfig

ARCH_NAMES = [
    "qwen2_5_32b",
    "granite_20b",
    "qwen3_1_7b",
    "llama3_405b",
    "whisper_small",
    "deepseek_v2_236b",
    "kimi_k2_1t",
    "chameleon_34b",
    "xlstm_1_3b",
    "jamba_52b",
]

# public ids used on the CLI (--arch) mapped to module names
ARCH_IDS = {
    "qwen2.5-32b": "qwen2_5_32b",
    "granite-20b": "granite_20b",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama3-405b": "llama3_405b",
    "whisper-small": "whisper_small",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "chameleon-34b": "chameleon_34b",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_52b",
}


class ShapeSpec(NamedTuple):
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec(4096, 256, "train"),
    "prefill_32k": ShapeSpec(32768, 32, "prefill"),
    "decode_32k": ShapeSpec(32768, 128, "decode"),
    "long_500k": ShapeSpec(524288, 1, "decode"),
    # context-parallel training: 1M tokens across a "seq" mesh axis
    # (dryrun --cp; the per-device scan sees seq_len/cp tokens)
    "train_1M": ShapeSpec(1048576, 16, "train"),
}


def _module(name: str):
    mod_name = ARCH_IDS.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).smoke_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_arch_ids():
    return list(ARCH_IDS.keys())
