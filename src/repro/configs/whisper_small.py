"""whisper-small [audio]: 12L(enc)+12L(dec) d=768 12H d_ff=3072 vocab=51865.
Enc-dec; conv frontend is a STUB (input_specs feeds frame embeddings).
[arXiv:2212.04356; unverified]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        vocab_size=51865, d_model=768, n_layers=12,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        pattern=("attn:mlp",),
        encoder_layers=12, encoder_seq=1500, cross_attention=True,
        rope_theta=0.0, pos_emb="sinusoidal",
        mlp_act="gelu", norm_type="layernorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=2, encoder_layers=2, encoder_seq=16,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
