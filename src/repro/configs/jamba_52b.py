"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2. Mamba+attn 1:7 interleave, MoE every other layer.
[arXiv:2403.19887; hf]"""
import dataclasses
from repro.attention import AttentionSpec
from repro.models.transformer import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        vocab_size=65536, d_model=4096, n_layers=32,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        pattern=("mamba:mlp", "mamba:moe", "mamba:mlp", "mamba:moe",
                 "attn:mlp", "mamba:moe", "mamba:mlp", "mamba:moe"),
        n_experts=16, moe_top_k=2, d_ff_expert=14336,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
        rope_theta=0.0,  # jamba uses no positional encoding
        mlp_act="swiglu", norm_type="rmsnorm",
        attn=AttentionSpec(family="fastmax", p=2), chunk_size=512,
        param_dtype="bfloat16", activ_dtype="bfloat16",
    )

def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), d_model=64, n_layers=8, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        n_experts=4, moe_top_k=2, d_ff_expert=64,
        param_dtype="float32", activ_dtype="float32", chunk_size=16)
