"""FAST / Fastmax attention — production JAX implementation.

The paper's contribution (Gerami et al., 2024): replace softmax's exp(q.k)
with a truncated-Taylor polynomial kernel f(x) = sum_{l<=p} x^l/l! applied to
statistically-normalized q, k. Because f is polynomial, the score O = A V
factorizes through key/value *moments* and costs O(N D^{p+1}) instead of
O(N^2 D).

Implementations provided (all numerically equivalent; validated against
`repro.core.ref`):

* ``impl='oracle'``    — O(N^2) reference (tests only).
* ``impl='rowwise'``   — the paper's own schedule: per-row prefix moments
                         (causal) / global moments (noncausal), explicit
                         phi-features. Supports the paper's three dropout
                         variants (Fig. 2). Memory O(N D^p) when causal —
                         kept for fidelity + small-model training.
* ``impl='chunked'``   — TPU-native chunked prefix-scan (DESIGN.md §2):
                         O(D^{p+1}) carry, MXU-shaped matmuls, optional
                         memory-reduced custom VJP (paper §2.5) that
                         reconstructs the scan carry *reversibly* in the
                         backward pass instead of storing it.
* ``impl='kernel'``    — Pallas TPU kernel (see `repro.kernels`).

Shape/GQA convention: q is [B, Hq, N, D]; k, v are [B, Hkv, N, D] with
Hq % Hkv == 0. Moments are computed once per kv-head and shared across the
query group (a beyond-paper efficiency the GPU reference code lacks).
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.ref import (
    fastmax_attention_ref,
    normalize_qk,
    poly_kernel,
)

__all__ = [
    "Moments",
    "fastmax_attention",
    "fastmax_noncausal",
    "fastmax_causal_chunked",
    "fastmax_rowwise",
    "compute_moments",
    "normalize_qk",
    "poly_kernel",
]


class Moments(NamedTuple):
    """Factorized key/value moments (paper Eqs. 28-29).

    Shapes (per batch x kv-head):
      m0: [..., Dv]        sum_n w_n v_n
      m1: [..., D, Dv]     sum_n w_n k_n v_n^T
      m2: [..., D, D, Dv]  sum_n w_n (k_n k_n^T) v_n   (p=2 only; zeros if p=1)
      g0: [...]            sum_n w_n
      g1: [..., D]         sum_n w_n k_n
      g2: [..., D, D]      sum_n w_n k_n k_n^T         (p=2 only)
    """

    m0: jnp.ndarray
    m1: jnp.ndarray
    m2: jnp.ndarray
    g0: jnp.ndarray
    g1: jnp.ndarray
    g2: jnp.ndarray

    def __add__(self, other: "Moments") -> "Moments":
        return Moments(*(a + b for a, b in zip(self, other)))

    def __sub__(self, other: "Moments") -> "Moments":
        return Moments(*(a - b for a, b in zip(self, other)))


def _f32(x):
    """Promote to at-least-float32 (bf16 -> f32; f64 stays f64)."""
    return x.astype(jnp.promote_types(x.dtype, jnp.float32))


def _acc_dtype(x):
    return jnp.promote_types(x.dtype, jnp.float32)


def _pick_bm(d: int) -> int:
    """m-block size for the XLA scan path: shared tiling policy
    (repro.kernels.tiling) at the 2048-row scan budget, so no intermediate
    larger than [..., n, bm*d] is ever materialized (the naive einsum builds
    [..., n, D, Dv] — gigabytes at production shapes)."""
    from repro.kernels.tiling import SCAN_BM_BUDGET, pick_bm
    return pick_bm(d, SCAN_BM_BUDGET)


def compute_moments(
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int,
    kv_mask: Optional[jnp.ndarray] = None,
    accum_dtype=None,
) -> Moments:
    """Moments of (k, v) over the token axis (axis=-2). k:[...,N,D] v:[...,N,Dv].

    `kv_mask` ([..., N], 1=valid) zeroes the contribution of padding tokens in
    BOTH numerator and denominator (exact: a masked key contributes nothing).
    """
    d = k.shape[-1]
    if accum_dtype is None:
        accum_dtype = _acc_dtype(k)
    if kv_mask is not None:
        w = kv_mask.astype(accum_dtype)
        kw = k * w[..., None]
        vw = v * w[..., None]
        g0 = jnp.sum(w, axis=-1)
    else:
        kw, vw = k, v
        g0 = jnp.full(k.shape[:-2], float(k.shape[-2]), dtype=accum_dtype)
    m0 = jnp.sum(vw, axis=-2, dtype=accum_dtype)
    m1 = jnp.einsum("...nm,...nj->...mj", kw, v, preferred_element_type=accum_dtype)
    g1 = jnp.sum(kw, axis=-2, dtype=accum_dtype)
    if p >= 2:
        # m-blocked: never materialize [..., N, D, D]
        bm = _pick_bm(d)
        dv = v.shape[-1]
        parts = []
        for s in range(0, d, bm):
            t = kw[..., :, s:s + bm, None] * k[..., :, None, :]
            t = t.reshape(*k.shape[:-1], bm * d)           # [..., N, bm*D]
            w2 = jnp.einsum("...nf,...nj->...fj", t, v,
                            preferred_element_type=accum_dtype)
            parts.append(w2.reshape(*k.shape[:-2], bm, d, dv))
        m2 = jnp.concatenate(parts, axis=-3)
        g2 = jnp.einsum("...nm,...nl->...ml", kw, k, preferred_element_type=accum_dtype)
    else:
        bshape = k.shape[:-2]
        m2 = jnp.zeros(bshape + (d, d, v.shape[-1]), accum_dtype)
        g2 = jnp.zeros(bshape + (d, d), accum_dtype)
    return Moments(m0, m1, m2, _f32(g0), g1, g2)


def combine_with_queries(q: jnp.ndarray, mom: Moments, *, p: int,
                         feature_shard: bool = False):
    """Per-query contraction with moments (paper Eqs. 26-27).

    q: [..., n, D]; moments broadcastable against q's batch dims.
    Returns (num [..., n, Dv], den [..., n]).

    `feature_shard=True` (serve path under tensor parallelism, kv heads not
    divisible by the 'model' axis): pin the queries replicated and every
    numerator intermediate to 'model' on its feature (Dv) dim, matching the
    moment shardings. Without these constraints XLA flip-flops between the
    head sharding q arrives with and the feature sharding the moments carry,
    and resolves the conflict by involuntarily rematerializing moment-sized
    tensors on every decode step (the TP=16 serve-path remat warnings).
    The only resharding left is the O(B Hq Dv) output — moment tensors never
    move.
    """
    qf = _f32(q)
    acc = qf.dtype
    if feature_shard:
        from repro.sharding.rules import maybe_constraint
        from repro.sharding.rules import replicate as _rep
        replicate = lambda x: _rep(x, batch_dim=0)  # noqa: E731 — keep DP
        qf = replicate(qf)
        feat = lambda x: maybe_constraint(  # noqa: E731 — 'model' on Dv
            x, ("pod", "data"), *((None,) * (x.ndim - 2) + ("model",)))
    else:
        feat = replicate = lambda x: x  # noqa: E731
    num = mom.m0[..., None, :] + jnp.einsum(
        "...nm,...mj->...nj", qf, mom.m1, preferred_element_type=acc
    )
    num = feat(num)
    den = mom.g0[..., None] + replicate(jnp.einsum(
        "...nm,...m->...n", qf, mom.g1, preferred_element_type=acc
    ))
    den = replicate(den)
    if p >= 2:
        d = qf.shape[-1]
        dv = mom.m2.shape[-1]
        bm = _pick_bm(d)
        num2 = None
        for s in range(0, d, bm):
            y = qf[..., :, s:s + bm, None] * qf[..., :, None, :]
            y = y.reshape(*qf.shape[:-1], bm * d)          # [..., n, bm*D]
            z = mom.m2[..., s:s + bm, :, :]
            z = z.reshape(*mom.m2.shape[:-3], bm * d, dv)  # [..., bm*D, Dv]
            c = feat(jnp.einsum("...nf,...fj->...nj", y, z,
                                preferred_element_type=acc))
            num2 = c if num2 is None else num2 + c
        num = feat(num + 0.5 * num2)
        # g2 is pinned model-REPLICATED (like all g-moments), so the q·g2
        # intermediate stays replicated too and the scalar contraction over
        # l is collective-free — sharding t here would force a partial-sum
        # + all-reduce per chunk for no moment-traffic saving
        t = jnp.einsum("...nm,...ml->...nl", qf, mom.g2,
                       preferred_element_type=acc)
        t = replicate(t)
        den = den + 0.5 * replicate(jnp.einsum(
            "...nl,...nl->...n", t, qf, preferred_element_type=acc))
        den = replicate(den)
    return num, den


# ---------------------------------------------------------------------------
# GQA plumbing
# ---------------------------------------------------------------------------


def _group_queries(q: jnp.ndarray, h_kv: int) -> jnp.ndarray:
    """[B, Hq, N, D] -> [B, Hkv, G, N, D]."""
    b, hq, n, d = q.shape
    if hq % h_kv != 0:
        raise ValueError(f"Hq={hq} not divisible by Hkv={h_kv}")
    return q.reshape(b, h_kv, hq // h_kv, n, d)


def _ungroup(o: jnp.ndarray) -> jnp.ndarray:
    """[B, Hkv, G, N, Dv] -> [B, Hq, N, Dv]."""
    b, hkv, g, n, dv = o.shape
    return o.reshape(b, hkv * g, n, dv)


# ---------------------------------------------------------------------------
# Noncausal factorized path
# ---------------------------------------------------------------------------


def compute_moments_chunked(
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int,
    kv_mask: Optional[jnp.ndarray] = None,
    chunk_size: int = 512,
    feature_shard: bool = False,
) -> Moments:
    """Full-sequence moments accumulated over N-chunks — peak memory
    O(chunk * bm * D) instead of O(N * bm * D).

    `feature_shard=True`: the scan runs sharding-aware — stacked chunks
    pinned to one total layout (`rules.shard_stacked`; v chunks Dv-sharded
    on 'model') and the carry feature-TP constrained, so the accumulated
    moments come out in the `_constrain_moments_j` layout without the
    partitioner rematerializing the stacked chunks.
    """
    b, hkv, m, d = k.shape
    if m <= chunk_size:
        mom = compute_moments(k, v, p=p, kv_mask=kv_mask)
        return _constrain_moments_j(mom) if feature_shard else mom
    nc = -(-m // chunk_size)
    pad = nc * chunk_size - m
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if kv_mask is None:
        mask = jnp.ones((b, hkv, m), dtype=jnp.float32)
    else:
        mask = kv_mask.astype(jnp.float32)
    maskp = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
    kc = jnp.moveaxis(kp.reshape(b, hkv, nc, chunk_size, d), 2, 0)
    vc = jnp.moveaxis(vp.reshape(b, hkv, nc, chunk_size, -1), 2, 0)
    mc = jnp.moveaxis(maskp.reshape(b, hkv, nc, chunk_size), 2, 0)
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        kc = shard_stacked(kc, seq_dim=0)
        vc = shard_stacked(vc, model_dim=-1, seq_dim=0)
        mc = shard_stacked(mc, seq_dim=0)

    def body(acc, xs):
        kc_i, vc_i, mc_i = xs
        new = acc + compute_moments(kc_i, vc_i, p=p, kv_mask=mc_i)
        if feature_shard:
            new = _constrain_moments_j(new)
        return new, None

    zero = jax.tree.map(
        jnp.zeros_like, compute_moments(kc[0], vc[0], p=p, kv_mask=mc[0])
    )
    if feature_shard:
        zero = _constrain_moments_j(zero)
    mom, _ = jax.lax.scan(body, zero, (kc, vc, mc))
    return mom


def _constrain_moments_j(mom: Moments) -> Moments:
    """Feature-TP: shard the value (Dv) dim of the m-moments over 'model' —
    the phi2 combine then splits TP-ways with no extra collectives (beyond
    the row-parallel wo psum). Beyond-paper: Megatron row-parallelism on
    the factorized-attention feature dim. The batch dim keeps its DP axes:
    a with_sharding_constraint is total, so leaving dim 0 out would force a
    batch all-gather of the moment state every step.

    The scalar g-moments are pinned model-REPLICATED (same layout the
    shard_map kernels and `decode_state_shardings` commit): they are
    Dv-times smaller than their m partners, and left unconstrained the
    partitioner shards g2's D dims — which back-propagates a D-sharding
    onto the scan-stacked q chunks and rematerializes them every chunk
    (the last 2 train_4k involuntary-remat warnings)."""
    from repro.sharding.rules import maybe_constraint, replicate

    def j_shard(x):
        if x.ndim < 3:
            return x
        return maybe_constraint(
            x, ("pod", "data"), *((None,) * (x.ndim - 2) + ("model",)))

    rep = lambda x: replicate(x, batch_dim=0)  # noqa: E731 — keep DP
    return Moments(j_shard(mom.m0), j_shard(mom.m1), j_shard(mom.m2),
                   rep(mom.g0), rep(mom.g1), rep(mom.g2))


def _combine_grouped(qg, mom: Moments, *, p: int, feature_shard=False):
    """combine_with_queries with the G axis FOLDED into the token axis —
    never builds a broadcast [.., Hkv, G, D, D, Dv] view of the moments
    (XLA reshapes of broadcasts force full rematerialization)."""
    b, hkv, g, n, d = qg.shape
    qf = qg.reshape(b, hkv, g * n, d)
    num, den = combine_with_queries(qf, mom, p=p, feature_shard=feature_shard)
    return (num.reshape(b, hkv, g, n, -1), den.reshape(b, hkv, g, n))


def fastmax_noncausal(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    kv_mask: Optional[jnp.ndarray] = None,
    denom_eps: float = 1e-6,
    chunk_size: int = 512,
    feature_shard: bool = False,
) -> jnp.ndarray:
    """Bidirectional fastmax. q:[B,Hq,N,D] k,v:[B,Hkv,M,*]. O(N D^{p+1})."""
    b, hkv, m, d = k.shape
    out_dtype = q.dtype
    mom = compute_moments_chunked(k, v, p=p, kv_mask=kv_mask,
                                  chunk_size=chunk_size,
                                  feature_shard=feature_shard)
    qg = _group_queries(q, hkv)
    num, den = _combine_grouped(qg, mom, p=p, feature_shard=feature_shard)
    o = num / (den + denom_eps)[..., None]
    return _ungroup(o).astype(out_dtype)


# ---------------------------------------------------------------------------
# Causal chunked scan (TPU-native schedule; DESIGN.md §2)
# ---------------------------------------------------------------------------


def _intra_chunk(qg, kc, vc, *, p, wc):
    """Exact within-chunk causal attention terms via the (small) B x B matrix.

    qg: [B,Hkv,G,c,D], kc: [B,Hkv,c,D], vc: [B,Hkv,c,Dv], wc: [B,Hkv,c].
    Returns (num [B,Hkv,G,c,Dv], den [B,Hkv,G,c]).
    """
    c = kc.shape[-2]
    acc = _acc_dtype(qg)
    s = jnp.einsum("...gnd,...md->...gnm", _f32(qg), _f32(kc),
                   preferred_element_type=acc)
    fs = poly_kernel(s, p)
    tri = jnp.tril(jnp.ones((c, c), dtype=acc))
    fs = fs * tri
    if wc is not None:
        fs = fs * wc[..., None, None, :].astype(acc)
    num = jnp.einsum("...gnm,...mj->...gnj", fs, _f32(vc),
                     preferred_element_type=acc)
    den = jnp.sum(fs, axis=-1)
    return num, den


def _causal_scan(q, k, v, *, p, chunk_size, kv_mask, denom_eps,
                 feature_shard=False, init: Optional[Moments] = None):
    """Chunked causal fastmax. Returns (o, final_moments).

    Carry = moments of all *previous* chunks; each chunk adds an exact
    intra-chunk term computed through the f(QK^T) block (same numbers as the
    factorized form, cheaper for the diagonal).

    `init` seeds the scan carry with existing moments (resumable prefill:
    the serving engine's chunked prefill continues a slot's moment state at
    an arbitrary token offset — queries in this call then attend to every
    token already folded into `init` plus the causal prefix of this call).

    `feature_shard=True` makes the scan sharding-aware end to end: the
    stacked chunk inputs are pinned to one total layout (q/k/w model-
    replicated with DP batch, v chunks Dv-sharded — `rules.shard_stacked`),
    the carry is feature-TP constrained every step, and the combine runs
    `combine_with_queries(feature_shard=True)` so each output chunk comes
    out Dv-sharded. Without the stacked-input pins, constraining only the
    carry makes the partitioner flip-flop the stacked tensors' layout
    between scan iterations — the measured 0→12 involuntary-remat
    regression on train_4k (ROADMAP) this closes.
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    cs = min(chunk_size, n)
    nc = -(-n // cs)
    pad = nc * cs - n

    if feature_shard:
        # pin the UNstacked inputs too: the pad/reshape/moveaxis chain (and
        # any residual XLA stashes across an outer layer-scan's remat
        # boundary) then derives ONE layout instead of a loop-local choice
        # that conflicts with the stacked pins below
        from repro.sharding.rules import shard_stacked
        q = shard_stacked(q, batch_dim=0)
        k = shard_stacked(k, batch_dim=0)
        v = shard_stacked(v, batch_dim=0, model_dim=-1)
    if kv_mask is None:
        w = jnp.ones((b, hkv, n), dtype=jnp.float32)
    else:
        w = kv_mask.astype(jnp.float32)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, pad)))

    qg = _group_queries(qp, hkv)  # [B,Hkv,G,Nc*cs,D]
    g = qg.shape[2]
    # chunk-major layout for scan
    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nc, cs, d), 3, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nc, cs, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nc, cs, dv), 2, 0)
    ws = jnp.moveaxis(wp.reshape(b, hkv, nc, cs), 2, 0)
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        # seq_dim=0: under a context-parallel mesh the stacked chunk runs
        # live on the devices owning those tokens (graceful no-op without
        # a "seq" axis or when nc doesn't divide)
        qs = shard_stacked(qs, seq_dim=0)
        ks = shard_stacked(ks, seq_dim=0)
        vs = shard_stacked(vs, model_dim=-1, seq_dim=0)
        ws = shard_stacked(ws, seq_dim=0)

    zero = jax.tree.map(
        jnp.zeros_like, compute_moments(ks[0], vs[0], p=p, kv_mask=ws[0])
    )
    if init is not None:
        # resume from an existing carry; match the scan's accumulator dtypes
        zero = Moments(*(i.astype(z.dtype) for z, i in zip(zero, init)))
    if feature_shard:
        zero = _constrain_moments_j(zero)

    def body(carry: Moments, xs):
        qc, kc, vc, wc = xs
        num_i, den_i = _combine_grouped(qc, carry, p=p,
                                        feature_shard=feature_shard)
        num_a, den_a = _intra_chunk(qc, kc, vc, p=p, wc=wc)
        o = (num_i + num_a) / (den_i + den_a + denom_eps)[..., None]
        if feature_shard:
            from repro.sharding.rules import shard_stacked
            # per-chunk output pinned Dv-on-'model' (batch keeps DP): the
            # stacked scan output then has ONE layout instead of whatever
            # each iteration's combine left behind
            o = shard_stacked(o, batch_dim=0, model_dim=-1)
        new_carry = carry + compute_moments(kc, vc, p=p, kv_mask=wc)
        if feature_shard:
            new_carry = _constrain_moments_j(new_carry)
        return new_carry, o

    final, os_ = jax.lax.scan(body, zero, (qs, ks, vs, ws))
    o = jnp.moveaxis(os_, 0, 3).reshape(b, hkv, g, nc * cs, dv)
    o = _ungroup(o)[:, :, :n]
    return o, final


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _causal_scan_cg(q, k, v, p, chunk_size, denom_eps, feature_shard=False):
    """Causal fastmax with the paper §2.5 memory-reduced custom gradient.

    Forward stores only (q, k, v, final moments): the backward pass
    reconstructs the scan carry at each chunk *reversibly* (moments are sums:
    carry_before = carry_after - delta_chunk) and re-applies autodiff to the
    chunk body. Memory O(N D) instead of O(N D^p) — the bound derived in
    paper §2.5.
    """
    o, _ = _causal_scan(q, k, v, p=p, chunk_size=chunk_size, kv_mask=None,
                        denom_eps=denom_eps, feature_shard=feature_shard)
    return o


def _causal_scan_cg_fwd(q, k, v, p, chunk_size, denom_eps,
                        feature_shard=False):
    o, final = _causal_scan(q, k, v, p=p, chunk_size=chunk_size, kv_mask=None,
                            denom_eps=denom_eps, feature_shard=feature_shard)
    return o, (q, k, v, final)


def _causal_scan_cg_bwd(p, chunk_size, denom_eps, feature_shard, res, do,
                        *, return_dstate=False):
    """§2.5 reverse scan. `return_dstate=True` (keyword-only, never set by
    the custom_vjp machinery) additionally returns the reverse scan's final
    carry-cotangent — the gradient of the scan's INITIAL moments. For an
    unseeded scan that cotangent is discarded (the initial carry is zeros);
    for a context-parallel shard seeded with the carry of the earlier
    shards it is exactly dC_i, the gradient those shards' moments receive."""
    q, k, v, final = res
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    cs = min(chunk_size, n)
    nc = -(-n // cs)
    pad = nc * cs - n

    if feature_shard:
        from repro.sharding.rules import shard_stacked
        q = shard_stacked(q, batch_dim=0)
        k = shard_stacked(k, batch_dim=0)
        v = shard_stacked(v, batch_dim=0, model_dim=-1)
        do = shard_stacked(do, batch_dim=0, model_dim=-1)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # same validity mask as the forward scan: zeros on padded tail tokens
    w = jnp.pad(jnp.ones((b, hkv, n), dtype=jnp.float32),
                ((0, 0), (0, 0), (0, pad)))

    qg = _group_queries(qp, hkv)
    g = qg.shape[2]
    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nc, cs, d), 3, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nc, cs, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nc, cs, dv), 2, 0)
    ws = jnp.moveaxis(w.reshape(b, hkv, nc, cs), 2, 0)
    dog = _group_queries(dop, hkv)
    dos = jnp.moveaxis(dog.reshape(b, hkv, g, nc, cs, dv), 3, 0)
    # the chunk forward emits fp32-accumulated outputs; a low-precision
    # cotangent (kernel path: do arrives in the input dtype) must be
    # promoted to match or jax.vjp rejects it
    dos = dos.astype(_acc_dtype(dos))
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        # mirror the forward scan's stacked-layout pins (the output
        # cotangent chunks carry the forward outputs' Dv sharding)
        qs = shard_stacked(qs)
        ks = shard_stacked(ks)
        vs = shard_stacked(vs, model_dim=-1)
        ws = shard_stacked(ws)
        dos = shard_stacked(dos, model_dim=-1)

    def chunk_fwd(carry: Moments, qc, kc, vc, wc):
        num_i, den_i = _combine_grouped(qc, carry, p=p,
                                        feature_shard=feature_shard)
        num_a, den_a = _intra_chunk(qc, kc, vc, p=p, wc=wc)
        return (num_i + num_a) / (den_i + den_a + denom_eps)[..., None]

    def rev_body(state, xs):
        carry_after, gcarry = state
        qc, kc, vc, wc, doc = xs
        delta = compute_moments(kc, vc, p=p, kv_mask=wc)
        carry_before = carry_after - delta
        if feature_shard:
            carry_before = _constrain_moments_j(carry_before)

        def f(carry, qc_, kc_, vc_):
            o = chunk_fwd(carry, qc_, kc_, vc_, wc)
            new_carry = carry + compute_moments(kc_, vc_, p=p, kv_mask=wc)
            if feature_shard:
                new_carry = _constrain_moments_j(new_carry)
            return o, new_carry

        _, vjp_fn = jax.vjp(f, carry_before, qc, kc, vc)
        gcarry_before, gq, gk, gv = vjp_fn((doc, gcarry))
        gcarry_before = Moments(*gcarry_before)
        if feature_shard:
            # the carry-cotangent is moment-shaped: same feature-TP layout;
            # the chunk cotangents mirror their primals' pins so the scan's
            # stacked output buffers get ONE layout too
            from repro.sharding.rules import shard_stacked
            gcarry_before = _constrain_moments_j(gcarry_before)
            gq = shard_stacked(gq, batch_dim=0)
            gk = shard_stacked(gk, batch_dim=0)
            gv = shard_stacked(gv, batch_dim=0, model_dim=-1)
        return (carry_before, gcarry_before), (gq, gk, gv)

    gzero = jax.tree.map(jnp.zeros_like, final)
    if feature_shard:
        gzero = _constrain_moments_j(gzero)
        final = _constrain_moments_j(final)
    (_, gfinal), (gqs, gks, gvs) = jax.lax.scan(
        rev_body, (final, gzero), (qs, ks, vs, ws, dos), reverse=True
    )
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        gqs = shard_stacked(gqs)
        gks = shard_stacked(gks)
        gvs = shard_stacked(gvs, model_dim=-1)
    gq = _ungroup(jnp.moveaxis(gqs, 0, 3).reshape(b, hkv, g, nc * cs, d))
    gk = jnp.moveaxis(gks, 0, 2).reshape(b, hkv, nc * cs, d)
    gv = jnp.moveaxis(gvs, 0, 2).reshape(b, hkv, nc * cs, dv)
    grads = (
        gq[:, :, :n].astype(q.dtype),
        gk[:, :, :n].astype(k.dtype),
        gv[:, :, :n].astype(v.dtype),
    )
    if return_dstate:
        return grads + (tuple(gfinal),)
    return grads


_causal_scan_cg.defvjp(_causal_scan_cg_fwd, _causal_scan_cg_bwd)


def fastmax_causal_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    chunk_size: int = 128,
    kv_mask: Optional[jnp.ndarray] = None,
    denom_eps: float = 1e-6,
    custom_grad: bool = True,
    feature_shard: bool = False,
) -> jnp.ndarray:
    out_dtype = q.dtype
    if custom_grad and kv_mask is None:
        o = _causal_scan_cg(q, k, v, p, chunk_size, denom_eps, feature_shard)
    else:
        o, _ = _causal_scan(q, k, v, p=p, chunk_size=chunk_size,
                            kv_mask=kv_mask, denom_eps=denom_eps,
                            feature_shard=feature_shard)
    return o.astype(out_dtype)


# ---------------------------------------------------------------------------
# Paper-faithful rowwise schedule (+ dropout variants, Fig. 2)
# ---------------------------------------------------------------------------


def _phi_features(x: jnp.ndarray, *, p: int, quad_mask=None) -> jnp.ndarray:
    """phi(x) with f(q.k) = phi(q).phi(k): [1, x, vec(x x^T)/sqrt(2)]."""
    parts = [jnp.ones(x.shape[:-1] + (1,), x.dtype), x]
    if p >= 2:
        d = x.shape[-1]
        outer = (x[..., :, None] * x[..., None, :]) / math.sqrt(2.0)
        outer = outer.reshape(x.shape[:-1] + (d * d,))
        if quad_mask is not None:
            outer = outer * quad_mask
        parts.append(outer)
    return jnp.concatenate(parts, axis=-1)


def fastmax_rowwise(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    denom_eps: float = 1e-6,
    dropout_rate: float = 0.0,
    dropout_mode: str = "quadratic",  # "quadratic" | "1d" | "none"
    dropout_rng: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """The paper's own schedule (Eqs. 26-35) via explicit phi features.

    Causal = running prefix sums over n of phi(k_n) [v_n; 1]^T — this is the
    O(N D^p)-memory layout the paper benchmarks (and that the chunked path
    supersedes). Supports the Fig. 2 dropout variants:
      * "quadratic": drop feature dims of the degree-2 block only (their best)
      * "1d": drop whole dims of q/k tokens before factorization
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    out_dtype = q.dtype
    qh = normalize_qk(_f32(q))
    kh = normalize_qk(_f32(k))

    quad_mask = None
    if dropout_rate > 0.0 and dropout_rng is not None:
        if dropout_mode == "quadratic" and p >= 2:
            keep = jax.random.bernoulli(
                dropout_rng, 1.0 - dropout_rate, shape=(b, hkv, 1, d * d)
            )
            quad_mask = keep.astype(jnp.float32) / (1.0 - dropout_rate)
        elif dropout_mode == "1d":
            keep_q = jax.random.bernoulli(dropout_rng, 1.0 - dropout_rate,
                                          shape=qh.shape)
            keep_k = jax.random.bernoulli(
                jax.random.fold_in(dropout_rng, 1), 1.0 - dropout_rate,
                shape=kh.shape)
            qh = qh * keep_q / (1.0 - dropout_rate)
            kh = kh * keep_k / (1.0 - dropout_rate)

    qg = _group_queries(qh, hkv)
    phq = _phi_features(qg, p=p,
                        quad_mask=None if quad_mask is None
                        else quad_mask[:, :, None])
    phk = _phi_features(kh, p=p, quad_mask=quad_mask)
    acc = _acc_dtype(q)
    v1 = jnp.concatenate([_f32(v), jnp.ones(v.shape[:-1] + (1,), acc)],
                         axis=-1)
    if causal:
        # running prefix of phi(k) [v;1]^T: [B,Hkv,N,Df,Dv+1] — the paper's
        # memory layout. Only use at small scale.
        outer = phk[..., :, None] * v1[..., None, :]
        pref = jnp.cumsum(outer, axis=-3)
        fg = jnp.einsum("...gnf,...nfj->...gnj", phq, pref,
                        preferred_element_type=acc)
    else:
        mom = jnp.einsum("...nf,...nj->...fj", phk, v1,
                         preferred_element_type=acc)
        fg = jnp.einsum("...gnf,...fj->...gnj", phq, mom,
                        preferred_element_type=acc)
    num, den = fg[..., :-1], fg[..., -1]
    o = num / (den + denom_eps)[..., None]
    return _ungroup(o).astype(out_dtype)


# ---------------------------------------------------------------------------
# Deprecated entry point (use repro.attention.attention)
# ---------------------------------------------------------------------------


def fastmax_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    normalize: bool = True,
    impl: str = "chunked",      # oracle | rowwise | chunked | kernel
    chunk_size: int = 128,
    kv_mask: Optional[jnp.ndarray] = None,
    denom_eps: float = 1e-6,
    custom_grad: bool = True,
    feature_shard: bool = False,
    dropout_rate: float = 0.0,
    dropout_mode: str = "quadratic",
    dropout_rng: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """DEPRECATED shim over `repro.attention.attention`.

    The 13-kwarg entry point is retired: build an `AttentionSpec` and call
    the dispatcher instead. Kept so external imports keep working; routing
    (dropout -> rowwise, etc.) now goes through the capability registry.
    `feature_shard` is re-derived from the active mesh by the dispatcher.
    """
    from repro.attention import AttentionSpec, attention

    del feature_shard  # re-derived by the dispatcher
    warnings.warn(
        "repro.core.fastmax_attention is deprecated; use "
        "repro.attention.attention(q, k, v, AttentionSpec(...))",
        DeprecationWarning, stacklevel=2)
    spec = AttentionSpec(
        family="fastmax", p=p, impl=impl, chunk_size=chunk_size,
        normalize=normalize, denom_eps=denom_eps, custom_grad=custom_grad,
        dropout_rate=dropout_rate, dropout_mode=dropout_mode)
    return attention(q, k, v, spec, causal=causal, kv_mask=kv_mask,
                     rng=dropout_rng)
