"""O(N^2) reference oracle for FAST / Fastmax attention (paper Eqs. 5-12).

This module materializes the full attention matrix and is used ONLY for
testing/validation at small N. The production paths (factorized / chunked /
Pallas) in `fastmax.py` and `repro.kernels` must match these outputs to
numerical tolerance.

Shape convention: q, k, v are `[..., N, D]` with arbitrary leading batch/head
dims. GQA is handled by callers (kv heads broadcast to q heads before entry).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = [
    "normalize_qk",
    "poly_kernel",
    "fastmax_attention_ref",
    "fastmax_attention_matrix_ref",
    "softmax_attention_ref",
]


def normalize_qk(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Paper Eqs. 5-6: per-token statistical normalization over the head dim.

    q_hat = (q - mean(q)) / std(q), std computed over the last axis.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True)
    return xc * jnp.reciprocal(jnp.sqrt(var + eps))


def poly_kernel(s: jnp.ndarray, p: int) -> jnp.ndarray:
    """Paper Eq. 8: f(x) = sum_{l=0..p} x^l / l!  (truncated Taylor of exp)."""
    if p < 0:
        raise ValueError(f"p must be >= 0, got {p}")
    out = jnp.ones_like(s)
    term = jnp.ones_like(s)
    for ell in range(1, p + 1):
        term = term * s / float(ell)
        out = out + term
    return out


def fastmax_attention_matrix_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    normalize: bool = True,
    denom_eps: float = 0.0,
) -> jnp.ndarray:
    """Full attention matrix A (paper Eq. 7/9). For tests and Fig. 4 maps."""
    if normalize:
        q = normalize_qk(q)
        k = normalize_qk(k)
    s = jnp.einsum("...nd,...md->...nm", q, k)
    fs = poly_kernel(s, p)
    if causal:
        n, m = fs.shape[-2], fs.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        fs = jnp.where(mask, fs, 0.0)
    g = jnp.sum(fs, axis=-1, keepdims=True)
    return fs / (g + denom_eps)


def fastmax_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    normalize: bool = True,
    denom_eps: float = 0.0,
) -> jnp.ndarray:
    """Score O = A V with A = Fastmax(Q K^T) (paper Eqs. 11-12)."""
    a = fastmax_attention_matrix_ref(
        q, k, p=p, causal=causal, normalize=normalize, denom_eps=denom_eps
    )
    return jnp.einsum("...nm,...mj->...nj", a, v)


def softmax_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    """Vanilla softmax attention baseline (paper Eqs. 1-4)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("...nd,...md->...nm", q, k) * scale
    if causal:
        n, m = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    a = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.einsum("...nm,...mj->...nj", a, v)
