"""Streaming/decode state primitives for Fastmax attention.

The asymptotic punchline of FAST at inference: the recurrent state of a
fastmax attention layer is its moment tuple — size
``Hkv * (1 + D + D^2) * (Dv + 1)`` floats, INDEPENDENT of context length.
A 32k- or 500k-token context costs the same per decoded token.

NOTE: the unified decode-state protocol (`init_state`/`prefill`/`step`
over the `AttnState` union, covering the softmax KV cache too) lives in
`repro.attention.state` and subsumes this module; these functions remain
as fastmax-level primitives / back-compat shims.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fastmax import (
    Moments,
    combine_with_queries,
    compute_moments,
    normalize_qk,
)

__all__ = ["init_fastmax_state", "fastmax_decode_step", "fastmax_prefill",
           "decode_state_bytes"]


def decode_state_bytes(cfg, batch: int, max_len: int) -> int:
    """Bytes of the full-model decode state for `batch` sequences of up to
    `max_len` tokens, WITHOUT allocating it (jax.eval_shape).

    This is the number the serving engine's slot accounting (and the
    BENCH_serve.json slot-memory cells) report: for fastmax specs it is
    INDEPENDENT of `max_len` (constant moment tuples), for the softmax
    baseline it grows linearly (KV cache rows) — the asymmetry that lets
    `repro.serve` batch 500k-context and 64-token requests into
    identically-sized slots with no paged-KV machinery.
    """
    from repro.models import decode_state_specs  # lazy: core must not
    #                                              import models at top level
    specs = decode_state_specs(cfg, batch, max_len)
    return int(sum(s.size * jnp.dtype(s.dtype).itemsize
                   for s in jax.tree.leaves(specs)))


def init_fastmax_state(
    batch: int, h_kv: int, d: int, dv: int, *, p: int = 2,
    dtype=jnp.float32,
) -> Moments:
    """Zero moments for a fresh sequence."""
    z = lambda *s: jnp.zeros((batch, h_kv) + s, dtype)
    return Moments(z(dv), z(d, dv), z(d, d, dv), z(), z(d), z(d, d))


def fastmax_prefill(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    p: int = 2, normalize: bool = True,
    kv_mask: Optional[jnp.ndarray] = None,
    chunk_size: int = 128, denom_eps: float = 1e-6,
):
    """Causal prefill returning (outputs, final Moments) for streaming decode."""
    from repro.core.fastmax import _causal_scan  # noqa: internal reuse

    qh = normalize_qk(q) if normalize else q
    kh = normalize_qk(k) if normalize else k
    o, final = _causal_scan(qh, kh, v, p=p, chunk_size=chunk_size,
                            kv_mask=kv_mask, denom_eps=denom_eps)
    return o, final


def fastmax_decode_step(
    state: Moments,
    q: jnp.ndarray,  # [B, Hq, 1, D]
    k: jnp.ndarray,  # [B, Hkv, 1, D]
    v: jnp.ndarray,  # [B, Hkv, 1, Dv]
    *,
    p: int = 2,
    normalize: bool = True,
    denom_eps: float = 1e-6,
):
    """One decode step: fold the new (k, v) into the moments, contract with q.

    O(D^{p} Dv) per head per token — no dependence on context length.
    Returns (o [B,Hq,1,Dv], new_state).
    """
    qh = normalize_qk(q) if normalize else q
    kh = normalize_qk(k) if normalize else k
    new_state = state + compute_moments(kh, v, p=p)
    hkv = k.shape[1]
    hq = q.shape[1]
    # fold the query group into the token axis (no broadcast of the state)
    qg = qh.reshape(q.shape[0], hkv, hq // hkv, q.shape[-1])
    num, den = combine_with_queries(qg, new_state, p=p)
    o = num / (den + denom_eps)[..., None]
    return o.reshape(q.shape[0], hq, 1, -1).astype(q.dtype), new_state
