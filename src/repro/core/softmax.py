"""Vanilla softmax attention baseline (paper Eqs. 1-4) with GQA + KV cache.

Implemented because the paper benchmarks against it everywhere (Fig. 3,
Tables 1-2, Fig. 6). Quadratic in N — `long_500k` is skipped for this
backend (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

__all__ = ["softmax_attention"]


def softmax_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """q: [B,Hq,N,D]; k,v: [B,Hkv,M,*]; Hq % Hkv == 0.

    `q_offset`: position of q[0] within the key timeline — used for decode
    (N=1, M=cache length) so causal masking stays correct.
    """
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    g = hq // hkv
    out_dtype = q.dtype
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, n, d).astype(jnp.float32)
    s = jnp.einsum("bhgnd,bhmd->bhgnm", qg, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qpos = jnp.arange(n)[:, None] + q_offset
        kpos = jnp.arange(m)[None, :]
        s = jnp.where((kpos <= qpos)[None, None, None], s, neg)
    if kv_mask is not None:
        s = jnp.where(kv_mask[:, :, None, None, :].astype(bool), s, neg)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    a = e / jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhgnm,bhmj->bhgnj", a, v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, n, -1).astype(out_dtype)
