"""Core: the paper's contribution — Fastmax factorizable attention."""
from repro.core.fastmax import (  # noqa: F401
    FastmaxConfig,
    Moments,
    compute_moments,
    fastmax_attention,
    fastmax_causal_chunked,
    fastmax_noncausal,
    fastmax_rowwise,
    normalize_qk,
    poly_kernel,
)
from repro.core.decode_state import (  # noqa: F401
    fastmax_decode_step,
    fastmax_prefill,
    init_fastmax_state,
)
from repro.core.softmax import softmax_attention  # noqa: F401
