"""Core: the paper's contribution — Fastmax factorizable attention.

NOTE: the public operator surface moved to `repro.attention`
(`AttentionSpec` + `attention(...)` + the `init_state`/`prefill`/`step`
decode protocol). The names re-exported here are implementation primitives
plus thin deprecation shims kept so external imports keep working.
"""
import warnings

from repro.core.fastmax import (  # noqa: F401
    Moments,
    compute_moments,
    fastmax_attention,
    fastmax_causal_chunked,
    fastmax_noncausal,
    fastmax_rowwise,
    normalize_qk,
    poly_kernel,
)
from repro.core.decode_state import (  # noqa: F401
    fastmax_decode_step,
    fastmax_prefill,
    init_fastmax_state,
)
from repro.core.softmax import softmax_attention  # noqa: F401


def __getattr__(name):
    if name == "FastmaxConfig":
        # retired NamedTuple, absorbed into repro.attention.AttentionSpec
        warnings.warn(
            "repro.core.FastmaxConfig is retired; use "
            "repro.attention.AttentionSpec", DeprecationWarning,
            stacklevel=2)
        from repro.attention import AttentionSpec
        return AttentionSpec
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
