"""Hybrid near/far-field attention (FMMformer-style, arXiv 2108.02347).

The source paper derives fastmax from the fast multipole method's
near/far-field factorization but ships only the far field. This module
fuses the two: an *exact* softmax over a width-`window` causal band (the
near field, where the polynomial truncation error concentrates) with the
fastmax p-th order moments over every off-band token (the far field),
combined in ONE normalizer so the result is a single well-defined
attention distribution.

Correction form: with normalized scores s_ij = q̂_i·k̂_j and the paper's
polynomial f_p(x) = sum_{l<=p} x^l / l!, the unnormalized weight is

    w_ij = f_p(s_ij)                           for all causal j  (moments)
         + [exp(s_ij) - f_p(s_ij)]             for j in the band (exact fix)

    o_i  = sum_j w_ij v_j / (sum_j w_ij + denom_eps)

The band is `i - j < w` including the diagonal (a token always sees
itself exactly). The moment leg is UNCHANGED from fastmax — the band
contributes only the (exp - f_p) correction, so there is no
double-counting and w=0 degenerates bitwise to fastmax, while w >= N is
exact softmax over the normalized scores.

Effective window: the band is clamped to one chunk,
``w_eff = min(window, chunk_size)`` — the chunked scan (and the Pallas
kernel) only ever looks one chunk back, so widening the band past the
chunk length requires raising chunk_size. Both the scan and the decode
state (repro.attention.state) apply the same clamp, keeping chunked
prefill and step-by-step decode in lockstep.

kv_mask removes masked keys from both legs exactly. Band *distances*
stay positional within one call and are valid-rank-based across resumed
prefill calls (the rolling window keeps the last `w` VALID tokens) — the
two agree for trailing padding (the only masking the serve engine
produces); interior masks would distort band distances across call
boundaries (documented limitation, see docs/hybrid.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fastmax import (
    Moments,
    _acc_dtype,
    _causal_scan,
    _causal_scan_cg_bwd,
    _combine_grouped,
    _constrain_moments_j,
    _f32,
    _group_queries,
    _intra_chunk,
    _ungroup,
    compute_moments,
    fastmax_causal_chunked,
)
from repro.core.ref import normalize_qk, poly_kernel

__all__ = [
    "effective_window",
    "hybrid_attention_ref",
    "hybrid_causal_chunked",
    "hybrid_bwd_scan",
    "roll_window",
]


def effective_window(window: int, chunk_size: int) -> int:
    """The band width the scan/kernel/decode paths actually realize."""
    return max(0, min(int(window), int(chunk_size)))


def _band_corr(qc, kc, vc, wc, band, *, p):
    """(exp - f_p) correction over a masked score block.

    qc: [B,Hkv,G,n,D], kc: [B,Hkv,m,D], vc: [B,Hkv,m,Dv], wc: [B,Hkv,m]
    validity (or None), band: [n,m] static mask. Returns
    (num [B,Hkv,G,n,Dv], den [B,Hkv,G,n]).
    """
    acc = _acc_dtype(qc)
    s = jnp.einsum("...gnd,...md->...gnm", _f32(qc), _f32(kc),
                   preferred_element_type=acc)
    corr = (jnp.exp(s) - poly_kernel(s, p)) * band.astype(acc)
    if wc is not None:
        corr = corr * wc[..., None, None, :].astype(acc)
    num = jnp.einsum("...gnm,...mj->...gnj", corr, _f32(vc),
                     preferred_element_type=acc)
    den = jnp.sum(corr, axis=-1)
    return num, den


def _band_masks(cs: int, w_eff: int, dtype=jnp.float32):
    """Static (intra, prev) band masks for chunk length `cs`.

    intra[i, m] — key m of the SAME chunk is in-band:   0 <= i-m < w_eff
    prev [i, m] — key m of the PREVIOUS chunk is:       i+cs-m  < w_eff
    (prev keys are always causally earlier, so no tril needed there).
    """
    i = jnp.arange(cs)[:, None]
    m = jnp.arange(cs)[None, :]
    intra = ((i >= m) & (i - m < w_eff)).astype(dtype)
    prev = ((i + cs - m) < w_eff).astype(dtype)
    return intra, prev


# ---------------------------------------------------------------------------
# Composed O(N^2) oracle (tests; f64-compared against scan and kernel)
# ---------------------------------------------------------------------------


def hybrid_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    window: int = 64,
    kv_mask: Optional[jnp.ndarray] = None,
    denom_eps: float = 1e-6,
    normalize: bool = True,
) -> jnp.ndarray:
    """Dense reference: banded exact softmax + masked fastmax, one
    normalizer. q:[B,Hq,N,D] k,v:[B,Hkv,N,*]. Causal only."""
    hkv = k.shape[1]
    n = q.shape[2]
    out_dtype = q.dtype
    qh, kh = _f32(q), _f32(k)
    if normalize:
        qh, kh = normalize_qk(qh), normalize_qk(kh)
    acc = qh.dtype
    qg = _group_queries(qh, hkv)
    s = jnp.einsum("...gnd,...md->...gnm", qg, kh,
                   preferred_element_type=acc)
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    tri = (i >= j).astype(acc)
    band = ((i >= j) & (i - j < window)).astype(acc)
    w = poly_kernel(s, p) * tri + (jnp.exp(s) - poly_kernel(s, p)) * band
    if kv_mask is not None:
        w = w * kv_mask[..., None, None, :].astype(acc)
    num = jnp.einsum("...gnm,...mj->...gnj", w, _f32(v),
                     preferred_element_type=acc)
    den = jnp.sum(w, axis=-1)
    o = num / (den + denom_eps)[..., None]
    return _ungroup(o).astype(out_dtype)


# ---------------------------------------------------------------------------
# Chunked causal scan (jnp oracle for the Pallas kernel + chunked backend)
# ---------------------------------------------------------------------------


def _hybrid_scan(q, k, v, *, p, window, chunk_size, kv_mask, denom_eps,
                 feature_shard=False, init: Optional[Moments] = None,
                 init_win=None):
    """Chunked causal hybrid. Returns (o, final_moments).

    Mirrors `fastmax._causal_scan` with an extended carry: besides the
    moments of all previous chunks, the previous chunk's (k, v, validity)
    ride along so the band correction can reach up to one chunk back
    (hence w_eff = min(window, cs)).

    `init` / `init_win` resume the scan (serving engine chunked prefill):
    `init` seeds the moment carry; `init_win` = (wk, wv, wm) is the
    rolling window of the last <=W tokens already folded, RIGHT-aligned
    (row W-1 = most recent). It is embedded into the last rows of a
    zeroed previous-chunk buffer — right alignment makes the prev-chunk
    distance formula (i + cs - m) land each carried token at exactly its
    token distance from this call's queries.

    Inputs q, k are expected already normalized (same convention as
    `_causal_scan`).
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    cs = min(chunk_size, n)
    if init_win is not None:
        # the carried window must fit inside one prev-chunk buffer
        cs = min(chunk_size, max(n, init_win[0].shape[2]))
    w_eff = effective_window(window, cs)
    if w_eff == 0:
        return _causal_scan(q, k, v, p=p, chunk_size=chunk_size,
                            kv_mask=kv_mask, denom_eps=denom_eps,
                            feature_shard=feature_shard, init=init)
    nc = -(-n // cs)
    pad = nc * cs - n

    if feature_shard:
        from repro.sharding.rules import shard_stacked
        q = shard_stacked(q, batch_dim=0)
        k = shard_stacked(k, batch_dim=0)
        v = shard_stacked(v, batch_dim=0, model_dim=-1)
    if kv_mask is None:
        w = jnp.ones((b, hkv, n), dtype=jnp.float32)
    else:
        w = kv_mask.astype(jnp.float32)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, pad)))

    qg = _group_queries(qp, hkv)
    g = qg.shape[2]
    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nc, cs, d), 3, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nc, cs, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nc, cs, dv), 2, 0)
    ws = jnp.moveaxis(wp.reshape(b, hkv, nc, cs), 2, 0)
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        qs = shard_stacked(qs, seq_dim=0)
        ks = shard_stacked(ks, seq_dim=0)
        vs = shard_stacked(vs, model_dim=-1, seq_dim=0)
        ws = shard_stacked(ws, seq_dim=0)

    intra_band, prev_band = _band_masks(cs, w_eff)

    zero = jax.tree.map(
        jnp.zeros_like, compute_moments(ks[0], vs[0], p=p, kv_mask=ws[0])
    )
    if init is not None:
        zero = Moments(*(i_.astype(z.dtype) for z, i_ in zip(zero, init)))
    if feature_shard:
        zero = _constrain_moments_j(zero)
    pk0 = jnp.zeros((b, hkv, cs, d), kp.dtype)
    pv0 = jnp.zeros((b, hkv, cs, dv), vp.dtype)
    pw0 = jnp.zeros((b, hkv, cs), jnp.float32)
    if init_win is not None:
        wk_, wv_, wm_ = init_win
        wlen = wk_.shape[2]
        pk0 = pk0.at[:, :, cs - wlen:].set(wk_.astype(pk0.dtype))
        pv0 = pv0.at[:, :, cs - wlen:].set(wv_.astype(pv0.dtype))
        pw0 = pw0.at[:, :, cs - wlen:].set(wm_.astype(pw0.dtype))

    def body(carry, xs):
        mom, pk, pv, pw = carry
        qc, kc, vc, wc = xs
        num_i, den_i = _combine_grouped(qc, mom, p=p,
                                        feature_shard=feature_shard)
        num_a, den_a = _intra_chunk(qc, kc, vc, p=p, wc=wc)
        num_b, den_b = _band_corr(qc, kc, vc, wc, intra_band, p=p)
        num_p, den_p = _band_corr(qc, pk, pv, pw, prev_band, p=p)
        num = num_i + num_a + num_b + num_p
        den = den_i + den_a + den_b + den_p
        o = num / (den + denom_eps)[..., None]
        if feature_shard:
            from repro.sharding.rules import shard_stacked
            o = shard_stacked(o, batch_dim=0, model_dim=-1)
        new_mom = mom + compute_moments(kc, vc, p=p, kv_mask=wc)
        if feature_shard:
            from repro.sharding.rules import shard_stacked
            new_mom = _constrain_moments_j(new_mom)
            kc = shard_stacked(kc, batch_dim=0)
            vc = shard_stacked(vc, batch_dim=0, model_dim=-1)
            wc = shard_stacked(wc, batch_dim=0)
        return (new_mom, kc, vc, wc), o

    (final, _, _, _), os_ = jax.lax.scan(
        body, (zero, pk0, pv0, pw0), (qs, ks, vs, ws))
    o = jnp.moveaxis(os_, 0, 3).reshape(b, hkv, g, nc * cs, dv)
    o = _ungroup(o)[:, :, :n]
    return o, final


def hybrid_bwd_scan(q, k, v, final: Moments, do, *, p, window, chunk_size,
                    denom_eps, feature_shard=False):
    """§2.5 reverse scan extended with band residuals. Returns (gq,gk,gv).

    Exactly the fastmax recomputation trick — the moment carry is
    reconstructed reversibly (carry_before = carry_after - delta) and the
    chunk forward re-autodiffed — plus the band extension: each chunk's
    forward also reads the PREVIOUS chunk's (k, v), so those ride along
    as shifted scan inputs and their cotangents are shift-added back
    after the scan (gk[c] += gk_prev[c+1]).

    Shared by the chunked custom_vjp and the Pallas kernel's backward
    (`final` then comes from the kernel's emitted carry).
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    cs = min(chunk_size, n)
    w_eff = effective_window(window, cs)
    if w_eff == 0:
        return _causal_scan_cg_bwd(p, chunk_size, denom_eps, feature_shard,
                                   (q, k, v, final), do)
    nc = -(-n // cs)
    pad = nc * cs - n

    if feature_shard:
        from repro.sharding.rules import shard_stacked
        q = shard_stacked(q, batch_dim=0)
        k = shard_stacked(k, batch_dim=0)
        v = shard_stacked(v, batch_dim=0, model_dim=-1)
        do = shard_stacked(do, batch_dim=0, model_dim=-1)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pad), (0, 0)))
    w = jnp.pad(jnp.ones((b, hkv, n), dtype=jnp.float32),
                ((0, 0), (0, 0), (0, pad)))

    qg = _group_queries(qp, hkv)
    g = qg.shape[2]
    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nc, cs, d), 3, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nc, cs, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nc, cs, dv), 2, 0)
    ws = jnp.moveaxis(w.reshape(b, hkv, nc, cs), 2, 0)
    dog = _group_queries(dop, hkv)
    dos = jnp.moveaxis(dog.reshape(b, hkv, g, nc, cs, dv), 3, 0)
    dos = dos.astype(_acc_dtype(dos))
    # the previous chunk's k/v/validity as shifted scan inputs
    kps = jnp.concatenate([jnp.zeros_like(ks[:1]), ks[:-1]], axis=0)
    vps = jnp.concatenate([jnp.zeros_like(vs[:1]), vs[:-1]], axis=0)
    wps = jnp.concatenate([jnp.zeros_like(ws[:1]), ws[:-1]], axis=0)
    if feature_shard:
        from repro.sharding.rules import shard_stacked
        qs = shard_stacked(qs)
        ks = shard_stacked(ks)
        vs = shard_stacked(vs, model_dim=-1)
        ws = shard_stacked(ws)
        dos = shard_stacked(dos, model_dim=-1)
        kps = shard_stacked(kps)
        vps = shard_stacked(vps, model_dim=-1)
        wps = shard_stacked(wps)

    intra_band, prev_band = _band_masks(cs, w_eff)

    def chunk_fwd(mom, qc, kc, vc, wc, kp_, vp_, wp_):
        num_i, den_i = _combine_grouped(qc, mom, p=p,
                                        feature_shard=feature_shard)
        num_a, den_a = _intra_chunk(qc, kc, vc, p=p, wc=wc)
        num_b, den_b = _band_corr(qc, kc, vc, wc, intra_band, p=p)
        num_p, den_p = _band_corr(qc, kp_, vp_, wp_, prev_band, p=p)
        num = num_i + num_a + num_b + num_p
        den = den_i + den_a + den_b + den_p
        return num / (den + denom_eps)[..., None]

    def rev_body(state, xs):
        mom_after, gmom = state
        qc, kc, vc, wc, kp_, vp_, wp_, doc = xs
        delta = compute_moments(kc, vc, p=p, kv_mask=wc)
        mom_before = mom_after - delta
        if feature_shard:
            mom_before = _constrain_moments_j(mom_before)

        def f(mom, qc_, kc_, vc_, kpp, vpp):
            o = chunk_fwd(mom, qc_, kc_, vc_, wc, kpp, vpp, wp_)
            new = mom + compute_moments(kc_, vc_, p=p, kv_mask=wc)
            if feature_shard:
                new = _constrain_moments_j(new)
            return o, new

        _, vjp_fn = jax.vjp(f, mom_before, qc, kc, vc, kp_, vp_)
        gmom_b, gq, gk, gv, gkp, gvp = vjp_fn((doc, gmom))
        gmom_b = Moments(*gmom_b)
        if feature_shard:
            from repro.sharding.rules import shard_stacked
            gmom_b = _constrain_moments_j(gmom_b)
            gq = shard_stacked(gq, batch_dim=0)
            gk = shard_stacked(gk, batch_dim=0)
            gv = shard_stacked(gv, batch_dim=0, model_dim=-1)
            gkp = shard_stacked(gkp, batch_dim=0)
            gvp = shard_stacked(gvp, batch_dim=0, model_dim=-1)
        return (mom_before, gmom_b), (gq, gk, gv, gkp, gvp)

    gzero = jax.tree.map(jnp.zeros_like, final)
    if feature_shard:
        gzero = _constrain_moments_j(gzero)
        final = _constrain_moments_j(final)
    _, (gqs, gks, gvs, gkps, gvps) = jax.lax.scan(
        rev_body, (final, gzero), (qs, ks, vs, ws, kps, vps, wps, dos),
        reverse=True)
    # chunk c's prev-key cotangent belongs to chunk c-1's keys
    gks = gks + jnp.concatenate(
        [gkps[1:], jnp.zeros_like(gkps[:1])], axis=0)
    gvs = gvs + jnp.concatenate(
        [gvps[1:], jnp.zeros_like(gvps[:1])], axis=0)
    gq = _ungroup(jnp.moveaxis(gqs, 0, 3).reshape(b, hkv, g, nc * cs, d))
    gk = jnp.moveaxis(gks, 0, 2).reshape(b, hkv, nc * cs, d)
    gv = jnp.moveaxis(gvs, 0, 2).reshape(b, hkv, nc * cs, dv)
    return (
        gq[:, :, :n].astype(q.dtype),
        gk[:, :, :n].astype(k.dtype),
        gv[:, :, :n].astype(v.dtype),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _hybrid_scan_cg(q, k, v, p, window, chunk_size, denom_eps,
                    feature_shard=False):
    """Hybrid causal scan with the §2.5 memory-reduced custom gradient."""
    o, _ = _hybrid_scan(q, k, v, p=p, window=window, chunk_size=chunk_size,
                        kv_mask=None, denom_eps=denom_eps,
                        feature_shard=feature_shard)
    return o


def _hybrid_scan_cg_fwd(q, k, v, p, window, chunk_size, denom_eps,
                        feature_shard=False):
    o, final = _hybrid_scan(q, k, v, p=p, window=window,
                            chunk_size=chunk_size, kv_mask=None,
                            denom_eps=denom_eps, feature_shard=feature_shard)
    return o, (q, k, v, final)


def _hybrid_scan_cg_bwd(p, window, chunk_size, denom_eps, feature_shard,
                        res, do):
    q, k, v, final = res
    return hybrid_bwd_scan(q, k, v, final, do, p=p, window=window,
                           chunk_size=chunk_size, denom_eps=denom_eps,
                           feature_shard=feature_shard)


_hybrid_scan_cg.defvjp(_hybrid_scan_cg_fwd, _hybrid_scan_cg_bwd)


def hybrid_causal_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    window: int = 64,
    chunk_size: int = 128,
    kv_mask: Optional[jnp.ndarray] = None,
    denom_eps: float = 1e-6,
    custom_grad: bool = True,
    feature_shard: bool = False,
) -> jnp.ndarray:
    """Public chunked entry. q, k already normalized (same convention as
    `fastmax_causal_chunked`); w_eff=0 delegates bitwise to fastmax."""
    out_dtype = q.dtype
    cs = min(chunk_size, q.shape[2])
    if effective_window(window, cs) == 0:
        return fastmax_causal_chunked(
            q, k, v, p=p, chunk_size=chunk_size, kv_mask=kv_mask,
            denom_eps=denom_eps, custom_grad=custom_grad,
            feature_shard=feature_shard)
    if custom_grad and kv_mask is None:
        o = _hybrid_scan_cg(q, k, v, p, window, chunk_size, denom_eps,
                            feature_shard)
    else:
        o, _ = _hybrid_scan(q, k, v, p=p, window=window,
                            chunk_size=chunk_size, kv_mask=kv_mask,
                            denom_eps=denom_eps, feature_shard=feature_shard)
    return o.astype(out_dtype)


# ---------------------------------------------------------------------------
# Rolling-window state helper (decode protocol)
# ---------------------------------------------------------------------------


def roll_window(wk, wv, wm, k, v, m, W: int):
    """Right-aligned "last W valid tokens" compaction.

    Concatenates the carried window (wk/wv/wm, may be None for a fresh
    state) with this call's (k, v, validity m) along the token axis and
    keeps the last W VALID entries, right-aligned: output row W-1 is the
    most recent valid token, unfilled rows have mask 0. Implemented as a
    rank-from-the-end one-hot contraction — ranks are unique so each
    output row receives at most one token, and it stays O(T·W·D) with no
    dynamic scatter (T = carried + chunk tokens).
    """
    if wk is None:
        ck, cv, cm = k, v, m
    else:
        ck = jnp.concatenate([wk.astype(k.dtype), k], axis=2)
        cv = jnp.concatenate([wv.astype(v.dtype), v], axis=2)
        cm = jnp.concatenate([wm.astype(m.dtype), m], axis=2)
    # rank r over valid entries counted from the end (r=1 most recent);
    # invalid entries get r=0 and are routed to the dropped dummy row W
    r = jnp.cumsum(cm[..., ::-1], axis=-1)[..., ::-1] * cm
    r = r.astype(jnp.int32)
    dest = jnp.where((r >= 1) & (r <= W), W - r, W)
    oh = dest[..., None] == jnp.arange(W, dtype=jnp.int32)
    nk = jnp.einsum("bhtw,bhtd->bhwd", oh.astype(ck.dtype), ck)
    nv = jnp.einsum("bhtw,bhtd->bhwd", oh.astype(cv.dtype), cv)
    nm = jnp.sum(oh.astype(jnp.float32), axis=2)
    return nk, nv, nm
