"""Pallas TPU kernel: fused causal Fastmax backward (paper §2.5).

The memory-reduced backward of the chunked causal forward
(`fastmax_causal.py`). The forward stores only (q, k, v, final moments);
this kernel walks the chunks in REVERSE along the sequential grid axis and,
per chunk, entirely in VMEM scratch:

  1. reconstructs the carry reversibly — moments are sums, so
     carry_before = carry_after − Δchunk (bit-exact: the subtraction mirrors
     the forward fold op-for-op),
  2. recomputes the chunk forward (inter-chunk moment contraction + exact
     intra-chunk f(QK^T) block) to get o, the output scale 1/(den+eps), and
     the denominator cotangent,
  3. emits dq (inter + intra terms), dk/dv (intra terms + the chain through
     this chunk's moment delta against the accumulated carry-cotangent),
  4. folds this chunk's moment-cotangent contributions into the carry-
     cotangent scratch for the chunks before it.

Dv-blocked carry (the 128×128-head enabler): the carry AND carry-cotangent
tuples are tiled over `nb = Dv/blk` value-feature column blocks along a
parallel grid axis — per-program scratch is two [D², blk] tuples
(~2·D²·blk·4 bytes) instead of two full [D², Dv] ones. The decomposition is
exact, not approximate: with u = do·deni restricted to a block and
sden_b = −Σ_j o_j u_j over the block's columns, EVERY backward term is
linear in (u_b, sden_b, and the per-block carry-cotangents they fold into),
while the nonlinear ingredients (den, 1/(den+eps), f'(QK^T), the mask) are
Dv-independent and recomputed identically per block from the redundantly
maintained g-carry. So

  dv  — slices: each block owns its Dv columns exactly;
  dq, dk — sum: the kernel emits per-block PARTIALS (leading nb axis, fp32
  accumulator dtype) and the wrapper reduces them in one XLA sum.

The same linearity is what makes the kernel shardable on Dv: a feature-TP
shard is just the blocks of its Dv slice, with the partial dq/dk psummed
across devices once per launch (`repro.kernels.sharded`).

Every heavy op is an MXU matmul; the degree-2 tensors stream in the same
m-major [bm·D, blk] blocks as the forward. Scratch is two moment tuples
(carry + carry-cotangent): O(D²·blk) bytes, independent of N — the §2.5
bound, now with zero HBM round-trips for the reconstruction AND a VMEM
footprint that fits production 128×128 heads (blk = pick_blk ⇒ nb = 2).

Validated in interpret mode against the jnp `_causal_scan_cg_bwd` oracle
and oracle autodiff (tests/test_kernels.py) over p ∈ {1,2}, GQA group
sizes, dtypes, and forced block widths (blk=1 ≡ blk=Dv bit-comparisons).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.fastmax_causal import _poly
from repro.kernels.tiling import BWD_BLK_BUDGET, pick_blk, pick_bm

__all__ = ["fastmax_causal_bwd_pallas"]


def _causal_bwd_kernel(
    q_ref,    # [1, G, C, D]
    k_ref,    # [1, C, D]
    v_ref,    # [1, C, BLK]    this program's Dv column block
    w_ref,    # [1, C]         validity mask (1=real token)
    do_ref,   # [1, G, C, BLK]
    fm0_ref,  # [1, 1, BLK]    final moments (read once, at the last chunk)
    fm1_ref,  # [1, D, BLK]
    fm2_ref,  # [1, M2R, BLK]  m-major
    fg0_ref,  # [1, 1, 1]      g-moments: full (Dv-independent)
    fg1_ref,  # [1, 1, D]
    fg2_ref,  # [1, D, D]
    dq_ref,   # [1, 1, G, C, D]  per-block PARTIAL (summed by the wrapper)
    dk_ref,   # [1, 1, C, D]     per-block PARTIAL
    dv_ref,   # [1, C, BLK]      exact slice
    *refs,    # [dstate outputs (return_dstate)] + 12 scratch buffers
    p: int,
    bm: int,
    denom_eps: float,
    acc,
    return_dstate: bool,
):
    if return_dstate:
        # cotangent of the scan's INITIAL carry — the m-cotangents are exact
        # Dv-column slices, the g-cotangents per-block partials (leading nb
        # output axis, reduced by the wrapper). Context parallelism reads
        # this as dC_i: the gradient each earlier shard's carry receives.
        (dsm0, dsm1, dsm2, dsg0, dsg1, dsg2) = refs[:6]
        refs = refs[6:]
    # scratch: carry moments + carry-cotangent moments (Dv-block columns)
    (m0_s, m1_s, m2_s, g0_s, g1_s, g2_s,
     gm0_s, gm1_s, gm2_s, gg0_s, gg1_s, gg2_s) = refs
    t = pl.program_id(2)   # reverse step: chunk = nc-1-t via the index maps
    g, cs, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    blk = v_ref.shape[2]
    gc = g * cs
    f32 = acc

    @pl.when(t == 0)
    def _init():
        m0_s[...] = fm0_ref[0]
        m1_s[...] = fm1_ref[0]
        g0_s[...] = fg0_ref[0]
        g1_s[...] = fg1_ref[0]
        gm0_s[...] = jnp.zeros_like(gm0_s)
        gm1_s[...] = jnp.zeros_like(gm1_s)
        gg0_s[...] = jnp.zeros_like(gg0_s)
        gg1_s[...] = jnp.zeros_like(gg1_s)
        if p >= 2:
            m2_s[...] = fm2_ref[0]
            g2_s[...] = fg2_ref[0]
            gm2_s[...] = jnp.zeros_like(gm2_s)
            gg2_s[...] = jnp.zeros_like(gg2_s)

    q = q_ref[0].astype(f32).reshape(gc, d)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    w = w_ref[0].astype(f32)
    do = do_ref[0].astype(f32).reshape(gc, blk)
    kw = k * w[:, None]
    vw = v * w[:, None]

    # ---- 1. reversible carry: carry_before = carry_after − Δchunk --------
    # (op-for-op mirror of the forward fold, so the subtraction is exact;
    # the g-carry is Dv-independent and maintained redundantly per block)
    m0_s[...] -= jnp.sum(vw, axis=0, keepdims=True)
    m1_s[...] -= jnp.dot(kw.T, v, preferred_element_type=f32)
    g0_s[...] -= jnp.sum(w).reshape(1, 1)
    g1_s[...] -= jnp.sum(kw, axis=0, keepdims=True)
    if p >= 2:
        g2_s[...] -= jnp.dot(kw.T, k, preferred_element_type=f32)

        def mb_down(i, _):
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)  # [C, bm]
            tt = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            m2_s[pl.dslice(i * bm * d, bm * d), :] -= jnp.dot(
                tt.T, vw, preferred_element_type=f32)
            return 0

        jax.lax.fori_loop(0, d // bm, mb_down, 0)

    # ---- 2. recompute the chunk forward against carry_before -------------
    # num: this block's Dv columns only; den: full (Dv-independent)
    num = jnp.broadcast_to(m0_s[...], (gc, blk)) + jnp.dot(
        q, m1_s[...], preferred_element_type=f32)
    den = g0_s[0, 0] + jnp.dot(q, g1_s[0], preferred_element_type=f32)
    if p >= 2:
        den = den + 0.5 * jnp.sum(
            jnp.dot(q, g2_s[...], preferred_element_type=f32) * q, axis=-1)

        def mb_num(i, a):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)
            y = (qm[:, :, None] * q[:, None, :]).reshape(gc, bm * d)
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]
            return a + jnp.dot(y, z, preferred_element_type=f32)

        num = num + 0.5 * jax.lax.fori_loop(
            0, d // bm, mb_num, jnp.zeros((gc, blk), f32))

    s_qk = jnp.dot(q, k.T, preferred_element_type=f32)   # [GC, C]
    qpos = jax.lax.broadcasted_iota(jnp.int32, (gc, cs), 0) % cs
    kpos = jax.lax.broadcasted_iota(jnp.int32, (gc, cs), 1)
    mask = (qpos >= kpos).astype(f32) * w[None, :]
    fs = _poly(s_qk, p) * mask
    num = num + jnp.dot(fs, v, preferred_element_type=f32)
    den = den + jnp.sum(fs, axis=-1)

    deni = 1.0 / (den + denom_eps)
    o = num * deni[:, None]                # this block's output columns
    u = do * deni[:, None]                 # dL/dnum (block columns)
    sden = -jnp.sum(o * u, axis=-1)        # block PARTIAL of dL/dden  [GC]

    # ---- 3a. intra-chunk grads through the f(QK^T) block ------------------
    # ds decomposes additively over Dv blocks: u@v^T contracts only this
    # block's columns and sden is the block partial, so Σ_blocks ds == full
    fprime = (1.0 + s_qk) if p >= 2 else jnp.ones_like(s_qk)
    ds = (jnp.dot(u, v.T, preferred_element_type=f32)
          + sden[:, None]) * fprime * mask
    dq = jnp.dot(ds, k, preferred_element_type=f32)      # [GC, D]
    dk = jnp.dot(ds.T, q, preferred_element_type=f32)    # [C, D]
    dvv = jnp.dot(fs.T, u, preferred_element_type=f32)   # [C, BLK]

    # ---- 3b. inter-chunk dq through the carry moments ---------------------
    dq += jnp.dot(u, m1_s[...].T, preferred_element_type=f32)
    dq += sden[:, None] * g1_s[0][None, :]
    if p >= 2:
        dq += sden[:, None] * jnp.dot(q, g2_s[...],
                                      preferred_element_type=f32)

        def mb_dq(i, a):
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]       # [bm*D, BLK]
            tmp = jnp.dot(u, z.T, preferred_element_type=f32)
            tmp = tmp.reshape(gc, bm, d)
            blk_ = jnp.sum(tmp * q[:, None, :], axis=-1)      # [GC, bm]
            return jax.lax.dynamic_update_slice(a, blk_, (0, i * bm))

        dq += jax.lax.fori_loop(0, d // bm, mb_dq,
                                jnp.zeros((gc, d), f32))

    # ---- 3c. dk/dv through this chunk's moment delta (uses the carry-
    # cotangent accumulated from LATER chunks — before step 4 updates it) ---
    dk += w[:, None] * jnp.dot(v, gm1_s[...].T, preferred_element_type=f32)
    dk += w[:, None] * gg1_s[0][None, :]
    dvv += w[:, None] * jnp.broadcast_to(gm0_s[...], (cs, blk))
    dvv += w[:, None] * jnp.dot(k, gm1_s[...], preferred_element_type=f32)
    if p >= 2:
        dk += 2.0 * w[:, None] * jnp.dot(k, gg2_s[...],
                                         preferred_element_type=f32)

        def mb_dkv(i, carry):
            dk_a, dv_a = carry
            z = gm2_s[pl.dslice(i * bm * d, bm * d), :]      # [bm*D, BLK]
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)
            tt = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            dv_a = dv_a + jnp.dot(tt, z, preferred_element_type=f32)
            tmp = jnp.dot(vw, z.T, preferred_element_type=f32)
            tmp = tmp.reshape(cs, bm, d)
            blk_ = 2.0 * jnp.sum(tmp * k[:, None, :], axis=-1)  # [C, bm]
            dk_a = jax.lax.dynamic_update_slice(dk_a, blk_, (0, i * bm))
            return dk_a, dv_a

        dk2, dv2 = jax.lax.fori_loop(
            0, d // bm, mb_dkv,
            (jnp.zeros((cs, d), f32), jnp.zeros((cs, blk), f32)))
        dk += dk2
        dvv += w[:, None] * dv2

    # ---- 4. fold this chunk's carry-cotangent for earlier chunks ----------
    # the gg-moments accumulate the block-PARTIAL sden, so the dk terms
    # they feed (step 3c) stay additively decomposed too
    gm0_s[...] += jnp.sum(u, axis=0, keepdims=True)
    gm1_s[...] += jnp.dot(q.T, u, preferred_element_type=f32)
    gg0_s[...] += jnp.sum(sden).reshape(1, 1)
    gg1_s[...] += jnp.sum(sden[:, None] * q, axis=0, keepdims=True)
    if p >= 2:
        gg2_s[...] += 0.5 * jnp.dot(q.T, q * sden[:, None],
                                    preferred_element_type=f32)

        def mb_gm2(i, _):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)
            y = (qm[:, :, None] * q[:, None, :]).reshape(gc, bm * d)
            gm2_s[pl.dslice(i * bm * d, bm * d), :] += 0.5 * jnp.dot(
                y.T, u, preferred_element_type=f32)
            return 0

        jax.lax.fori_loop(0, d // bm, mb_gm2, 0)

    dq_ref[0, 0] = dq.reshape(g, cs, d).astype(dq_ref.dtype)
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dvv.astype(dv_ref.dtype)

    if return_dstate:
        nc = pl.num_programs(2)

        @pl.when(t == nc - 1)
        def _emit_dstate():
            # after folding chunk 0 (step 4 above) the carry-cotangent
            # scratch IS d(initial carry) — every local chunk's use of the
            # seeded moments has been chained through
            dsm0[0] = gm0_s[...]
            dsm1[0] = gm1_s[...]
            dsg0[0, 0] = gg0_s[...]
            dsg1[0, 0] = gg1_s[...]
            if p >= 2:
                dsm2[0] = gm2_s[...]
                dsg2[0, 0] = gg2_s[...]
            else:
                dsm2[0] = jnp.zeros_like(dsm2[0])
                dsg2[0, 0] = jnp.zeros_like(dsg2[0, 0])


@functools.partial(
    jax.jit,
    static_argnames=("p", "chunk_size", "denom_eps", "interpret", "blk",
                     "bm", "grid", "return_dstate"),
)
def fastmax_causal_bwd_pallas(
    q: jnp.ndarray,   # [B, Hq, N, D]   (pre-normalized q̂, as in the fwd)
    k: jnp.ndarray,   # [B, Hkv, N, D]
    v: jnp.ndarray,   # [B, Hkv, N, Dv]
    state: tuple,     # final moments: ([B,Hkv,Dv], [B,Hkv,D,Dv],
    #                   [B,Hkv,D,D,Dv], [B,Hkv], [B,Hkv,D], [B,Hkv,D,D])
    do: jnp.ndarray,  # [B, Hq, N, Dv]  output cotangent
    *,
    p: int = 2,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool = False,
    blk: int | None = None,
    bm: int | None = None,
    grid: str | None = None,
    return_dstate: bool = False,
):
    """Returns (dq, dk, dv) in the input dtypes. With `return_dstate=True`
    additionally returns the cotangent of the scan's initial carry as a
    moment-layout tuple ([B,Hkv,Dv], [B,Hkv,D,Dv], [B,Hkv,D,D,Dv], [B,Hkv],
    [B,Hkv,D], [B,Hkv,D,D]) in the accumulator dtype. When the forward was
    seeded with an initial state (context-parallel shards), `state` must be
    that SEEDED forward's final carry; the reversible subtraction then
    reconstructs down to the seed and the emitted cotangent is exactly the
    gradient the seed — i.e. every earlier shard's moment delta — receives.

    `blk` is the Dv carry-block width (must divide Dv); None picks the
    largest divisor keeping BOTH degree-2 scratch tuples under
    `BWD_BLK_BUDGET` each — nb = Dv/blk = 1 (the unblocked schedule) up to
    64×64 heads, nb = 2 at 128×128. Feature-TP callers pass their LOCAL Dv
    shard; the emitted dq/dk are then the shard's partials (psummed once
    per launch by `repro.kernels.sharded`). `bm` (m-major row block, must
    divide D) and `grid` ("parallel"|"arbitrary" for the independent grid
    axes) are the autotuner's remaining schedule knobs; None keeps the
    untuned defaults.
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    if hq % hkv:
        raise ValueError(f"Hq={hq} % Hkv={hkv} != 0")
    bh = b * hkv
    acc = jnp.promote_types(q.dtype, jnp.float32)

    cs = min(chunk_size, max(8, n))
    nc = -(-n // cs)
    pad = nc * cs - n
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, d).reshape(bh, g, nc * cs, d)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        bh, nc * cs, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        bh, nc * cs, dv)
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, dv).reshape(bh, g, nc * cs, dv)
    w = jnp.pad(jnp.ones((bh, n), acc), ((0, 0), (0, pad)))

    m0, m1, m2, g0, g1, g2 = state
    m2_rows = d * d if p >= 2 else 1
    fm0 = m0.reshape(bh, 1, dv).astype(acc)
    fm1 = m1.reshape(bh, d, dv).astype(acc)
    fm2 = (m2.reshape(bh, d * d, dv).astype(acc) if p >= 2
           else jnp.zeros((bh, 1, dv), acc))
    fg0 = g0.reshape(bh, 1, 1).astype(acc)
    fg1 = g1.reshape(bh, 1, d).astype(acc)
    fg2 = g2.reshape(bh, d, d).astype(acc)

    if bm is None:
        bm = pick_bm(d)
    if d % bm:
        raise ValueError(f"bm={bm} must divide D={d}")
    if blk is None:
        blk = pick_blk(d, dv, BWD_BLK_BUDGET)
    if dv % blk:
        raise ValueError(f"blk={blk} must divide Dv={dv}")
    if grid is None:
        grid = "parallel"
    if grid not in ("parallel", "arbitrary"):
        raise ValueError(f"grid={grid!r}; expected 'parallel'|'arbitrary'")
    par = "parallel" if grid == "parallel" else "arbitrary"
    nb = dv // blk
    kernel = functools.partial(_causal_bwd_kernel, p=p, bm=bm,
                               denom_eps=denom_eps, acc=acc,
                               return_dstate=return_dstate)
    rev = lambda h, b_, t: (h, nc - 1 - t, 0)        # noqa: E731 rev chunks
    revb = lambda h, b_, t: (h, nc - 1 - t, b_)      # noqa: E731 + Dv block
    revq = lambda h, b_, t: (h, 0, nc - 1 - t, 0)    # noqa: E731
    revqb = lambda h, b_, t: (h, 0, nc - 1 - t, b_)  # noqa: E731
    vb = lambda h, b_, t: (h, 0, b_)                 # noqa: E731 m-state
    sm = lambda h, b_, t: (h, 0, 0)                  # noqa: E731 g-state
    # dq/dk come back as per-Dv-block fp32 partials (leading nb axis) and
    # are reduced here: every backward term is linear in the block-local
    # cotangents, so the sum over blocks is the exact full gradient
    out_specs = [
        pl.BlockSpec((1, 1, g, cs, d),
                     lambda h, b_, t: (h, b_, 0, nc - 1 - t, 0)),
        pl.BlockSpec((1, 1, cs, d),
                     lambda h, b_, t: (h, b_, nc - 1 - t, 0)),
        pl.BlockSpec((1, cs, blk), revb),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bh, nb, g, nc * cs, d), acc),
        jax.ShapeDtypeStruct((bh, nb, nc * cs, d), acc),
        jax.ShapeDtypeStruct((bh, nc * cs, dv), v.dtype),
    ]
    if return_dstate:
        # m-cotangents slice cleanly over Dv (vb); g-cotangents are built
        # from the block-partial sden, so they carry a leading nb axis and
        # are reduced below — the same partial/slice split as dq/dk vs dv
        nbm = lambda h, b_, t: (h, b_, 0, 0)         # noqa: E731
        out_specs += [
            pl.BlockSpec((1, 1, blk), vb),
            pl.BlockSpec((1, d, blk), vb),
            pl.BlockSpec((1, m2_rows, blk), vb),
            pl.BlockSpec((1, 1, 1, 1), nbm),
            pl.BlockSpec((1, 1, 1, d), nbm),
            pl.BlockSpec((1, 1, d, d), nbm),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((bh, 1, dv), acc),
            jax.ShapeDtypeStruct((bh, d, dv), acc),
            jax.ShapeDtypeStruct((bh, m2_rows, dv), acc),
            jax.ShapeDtypeStruct((bh, nb, 1, 1), acc),
            jax.ShapeDtypeStruct((bh, nb, 1, d), acc),
            jax.ShapeDtypeStruct((bh, nb, d, d), acc),
        ]
    outs = pl.pallas_call(
        kernel,
        grid=(bh, nb, nc),
        in_specs=[
            pl.BlockSpec((1, g, cs, d), revq),
            pl.BlockSpec((1, cs, d), rev),
            pl.BlockSpec((1, cs, blk), revb),
            pl.BlockSpec((1, cs), lambda h, b_, t: (h, nc - 1 - t)),
            pl.BlockSpec((1, g, cs, blk), revqb),
            pl.BlockSpec((1, 1, blk), vb),
            pl.BlockSpec((1, d, blk), vb),
            pl.BlockSpec((1, m2_rows, blk), vb),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((1, blk), acc),
            pltpu.VMEM((d, blk), acc),
            pltpu.VMEM((m2_rows, blk), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
            pltpu.VMEM((1, blk), acc),
            pltpu.VMEM((d, blk), acc),
            pltpu.VMEM((m2_rows, blk), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
        ],
        compiler_params=tpu_compiler_params((par, par, "arbitrary")),
        interpret=interpret,
        name=f"fastmax_causal_bwd_p{p}",
    )(qp, kp, vp, w, dop, fm0, fm1, fm2, fg0, fg1, fg2)

    dq_p, dk_p, dvv = outs[:3]
    dq = jnp.sum(dq_p, axis=1).astype(q.dtype)
    dk = jnp.sum(dk_p, axis=1).astype(k.dtype)
    dq = dq.reshape(b, hkv, g, nc * cs, d)[:, :, :, :n].reshape(b, hq, n, d)
    dk = dk.reshape(b, hkv, nc * cs, d)[:, :, :n]
    dvv = dvv.reshape(b, hkv, nc * cs, dv)[:, :, :n]
    if not return_dstate:
        return dq, dk, dvv
    dsm0, dsm1, dsm2, dsg0, dsg1, dsg2 = outs[3:]
    dstate = (
        dsm0.reshape(b, hkv, dv),
        dsm1.reshape(b, hkv, d, dv),
        (dsm2.reshape(b, hkv, d, d, dv) if p >= 2
         else jnp.zeros((b, hkv, d, d, dv), acc)),
        jnp.sum(dsg0, axis=1).reshape(b, hkv),
        jnp.sum(dsg1, axis=1).reshape(b, hkv, d),
        jnp.sum(dsg2, axis=1).reshape(b, hkv, d, d),
    )
    return dq, dk, dvv, dstate
