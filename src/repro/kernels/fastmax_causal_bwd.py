"""Pallas TPU kernel: fused causal Fastmax backward (paper §2.5).

The memory-reduced backward of the chunked causal forward
(`fastmax_causal.py`). The forward stores only (q, k, v, final moments);
this kernel walks the chunks in REVERSE along the sequential grid axis and,
per chunk, entirely in VMEM scratch:

  1. reconstructs the carry reversibly — moments are sums, so
     carry_before = carry_after − Δchunk (bit-exact: the subtraction mirrors
     the forward fold op-for-op),
  2. recomputes the chunk forward (inter-chunk moment contraction + exact
     intra-chunk f(QK^T) block) to get o, the output scale 1/(den+eps), and
     the denominator cotangent,
  3. emits dq (inter + intra terms), dk/dv (intra terms + the chain through
     this chunk's moment delta against the accumulated carry-cotangent),
  4. folds this chunk's moment-cotangent contributions into the carry-
     cotangent scratch for the chunks before it.

Every heavy op is an MXU matmul; the degree-2 tensors stream in the same
m-major [bm·D, Dv] blocks as the forward. Scratch is two moment tuples
(carry + carry-cotangent): O(D^{p+1}) bytes, independent of N — the §2.5
bound, now with zero HBM round-trips for the reconstruction.

Validated in interpret mode against the jnp `_causal_scan_cg_bwd` oracle
and oracle autodiff (tests/test_kernels.py) over p ∈ {1,2}, GQA group
sizes, and dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.fastmax_causal import _poly
from repro.kernels.tiling import pick_bm

__all__ = ["fastmax_causal_bwd_pallas"]


def _causal_bwd_kernel(
    q_ref,    # [1, G, C, D]
    k_ref,    # [1, C, D]
    v_ref,    # [1, C, Dv]
    w_ref,    # [1, C]        validity mask (1=real token)
    do_ref,   # [1, G, C, Dv]
    fm0_ref,  # [1, 1, Dv]    final moments (read once, at the last chunk)
    fm1_ref,  # [1, D, Dv]
    fm2_ref,  # [1, M2R, Dv]  m-major
    fg0_ref,  # [1, 1, 1]
    fg1_ref,  # [1, 1, D]
    fg2_ref,  # [1, D, D]
    dq_ref,   # [1, G, C, D]
    dk_ref,   # [1, C, D]
    dv_ref,   # [1, C, Dv]
    # scratch: carry moments + carry-cotangent moments
    m0_s, m1_s, m2_s, g0_s, g1_s, g2_s,
    gm0_s, gm1_s, gm2_s, gg0_s, gg1_s, gg2_s,
    *,
    p: int,
    bm: int,
    denom_eps: float,
    acc,
):
    t = pl.program_id(1)   # reverse step: chunk = nc-1-t via the index maps
    g, cs, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = v_ref.shape[2]
    gc = g * cs
    f32 = acc

    @pl.when(t == 0)
    def _init():
        m0_s[...] = fm0_ref[0]
        m1_s[...] = fm1_ref[0]
        g0_s[...] = fg0_ref[0]
        g1_s[...] = fg1_ref[0]
        gm0_s[...] = jnp.zeros_like(gm0_s)
        gm1_s[...] = jnp.zeros_like(gm1_s)
        gg0_s[...] = jnp.zeros_like(gg0_s)
        gg1_s[...] = jnp.zeros_like(gg1_s)
        if p >= 2:
            m2_s[...] = fm2_ref[0]
            g2_s[...] = fg2_ref[0]
            gm2_s[...] = jnp.zeros_like(gm2_s)
            gg2_s[...] = jnp.zeros_like(gg2_s)

    q = q_ref[0].astype(f32).reshape(gc, d)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    w = w_ref[0].astype(f32)
    do = do_ref[0].astype(f32).reshape(gc, dv)
    kw = k * w[:, None]
    vw = v * w[:, None]

    # ---- 1. reversible carry: carry_before = carry_after − Δchunk --------
    # (op-for-op mirror of the forward fold, so the subtraction is exact)
    m0_s[...] -= jnp.sum(vw, axis=0, keepdims=True)
    m1_s[...] -= jnp.dot(kw.T, v, preferred_element_type=f32)
    g0_s[...] -= jnp.sum(w).reshape(1, 1)
    g1_s[...] -= jnp.sum(kw, axis=0, keepdims=True)
    if p >= 2:
        g2_s[...] -= jnp.dot(kw.T, k, preferred_element_type=f32)

        def mb_down(i, _):
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)  # [C, bm]
            tt = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            m2_s[pl.dslice(i * bm * d, bm * d), :] -= jnp.dot(
                tt.T, vw, preferred_element_type=f32)
            return 0

        jax.lax.fori_loop(0, d // bm, mb_down, 0)

    # ---- 2. recompute the chunk forward against carry_before -------------
    num = jnp.broadcast_to(m0_s[...], (gc, dv)) + jnp.dot(
        q, m1_s[...], preferred_element_type=f32)
    den = g0_s[0, 0] + jnp.dot(q, g1_s[0], preferred_element_type=f32)
    if p >= 2:
        den = den + 0.5 * jnp.sum(
            jnp.dot(q, g2_s[...], preferred_element_type=f32) * q, axis=-1)

        def mb_num(i, a):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)
            y = (qm[:, :, None] * q[:, None, :]).reshape(gc, bm * d)
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]
            return a + jnp.dot(y, z, preferred_element_type=f32)

        num = num + 0.5 * jax.lax.fori_loop(
            0, d // bm, mb_num, jnp.zeros((gc, dv), f32))

    s_qk = jnp.dot(q, k.T, preferred_element_type=f32)   # [GC, C]
    qpos = jax.lax.broadcasted_iota(jnp.int32, (gc, cs), 0) % cs
    kpos = jax.lax.broadcasted_iota(jnp.int32, (gc, cs), 1)
    mask = (qpos >= kpos).astype(f32) * w[None, :]
    fs = _poly(s_qk, p) * mask
    num = num + jnp.dot(fs, v, preferred_element_type=f32)
    den = den + jnp.sum(fs, axis=-1)

    deni = 1.0 / (den + denom_eps)
    o = num * deni[:, None]
    u = do * deni[:, None]                 # dL/dnum
    sden = -jnp.sum(o * u, axis=-1)        # dL/dden  [GC]

    # ---- 3a. intra-chunk grads through the f(QK^T) block ------------------
    fprime = (1.0 + s_qk) if p >= 2 else jnp.ones_like(s_qk)
    ds = (jnp.dot(u, v.T, preferred_element_type=f32)
          + sden[:, None]) * fprime * mask
    dq = jnp.dot(ds, k, preferred_element_type=f32)      # [GC, D]
    dk = jnp.dot(ds.T, q, preferred_element_type=f32)    # [C, D]
    dvv = jnp.dot(fs.T, u, preferred_element_type=f32)   # [C, Dv]

    # ---- 3b. inter-chunk dq through the carry moments ---------------------
    dq += jnp.dot(u, m1_s[...].T, preferred_element_type=f32)
    dq += sden[:, None] * g1_s[0][None, :]
    if p >= 2:
        dq += sden[:, None] * jnp.dot(q, g2_s[...],
                                      preferred_element_type=f32)

        def mb_dq(i, a):
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]       # [bm*D, Dv]
            tmp = jnp.dot(u, z.T, preferred_element_type=f32)
            tmp = tmp.reshape(gc, bm, d)
            blk = jnp.sum(tmp * q[:, None, :], axis=-1)       # [GC, bm]
            return jax.lax.dynamic_update_slice(a, blk, (0, i * bm))

        dq += jax.lax.fori_loop(0, d // bm, mb_dq,
                                jnp.zeros((gc, d), f32))

    # ---- 3c. dk/dv through this chunk's moment delta (uses the carry-
    # cotangent accumulated from LATER chunks — before step 4 updates it) ---
    dk += w[:, None] * jnp.dot(v, gm1_s[...].T, preferred_element_type=f32)
    dk += w[:, None] * gg1_s[0][None, :]
    dvv += w[:, None] * jnp.broadcast_to(gm0_s[...], (cs, dv))
    dvv += w[:, None] * jnp.dot(k, gm1_s[...], preferred_element_type=f32)
    if p >= 2:
        dk += 2.0 * w[:, None] * jnp.dot(k, gg2_s[...],
                                         preferred_element_type=f32)

        def mb_dkv(i, carry):
            dk_a, dv_a = carry
            z = gm2_s[pl.dslice(i * bm * d, bm * d), :]      # [bm*D, Dv]
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)
            tt = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            dv_a = dv_a + jnp.dot(tt, z, preferred_element_type=f32)
            tmp = jnp.dot(vw, z.T, preferred_element_type=f32)
            tmp = tmp.reshape(cs, bm, d)
            blk = 2.0 * jnp.sum(tmp * k[:, None, :], axis=-1)  # [C, bm]
            dk_a = jax.lax.dynamic_update_slice(dk_a, blk, (0, i * bm))
            return dk_a, dv_a

        dk2, dv2 = jax.lax.fori_loop(
            0, d // bm, mb_dkv,
            (jnp.zeros((cs, d), f32), jnp.zeros((cs, dv), f32)))
        dk += dk2
        dvv += w[:, None] * dv2

    # ---- 4. fold this chunk's carry-cotangent for earlier chunks ----------
    gm0_s[...] += jnp.sum(u, axis=0, keepdims=True)
    gm1_s[...] += jnp.dot(q.T, u, preferred_element_type=f32)
    gg0_s[...] += jnp.sum(sden).reshape(1, 1)
    gg1_s[...] += jnp.sum(sden[:, None] * q, axis=0, keepdims=True)
    if p >= 2:
        gg2_s[...] += 0.5 * jnp.dot(q.T, q * sden[:, None],
                                    preferred_element_type=f32)

        def mb_gm2(i, _):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)
            y = (qm[:, :, None] * q[:, None, :]).reshape(gc, bm * d)
            gm2_s[pl.dslice(i * bm * d, bm * d), :] += 0.5 * jnp.dot(
                y.T, u, preferred_element_type=f32)
            return 0

        jax.lax.fori_loop(0, d // bm, mb_gm2, 0)

    dq_ref[0] = dq.reshape(g, cs, d).astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dvv.astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("p", "chunk_size", "denom_eps", "interpret"),
)
def fastmax_causal_bwd_pallas(
    q: jnp.ndarray,   # [B, Hq, N, D]   (pre-normalized q̂, as in the fwd)
    k: jnp.ndarray,   # [B, Hkv, N, D]
    v: jnp.ndarray,   # [B, Hkv, N, Dv]
    state: tuple,     # final moments: ([B,Hkv,Dv], [B,Hkv,D,Dv],
    #                   [B,Hkv,D,D,Dv], [B,Hkv], [B,Hkv,D], [B,Hkv,D,D])
    do: jnp.ndarray,  # [B, Hq, N, Dv]  output cotangent
    *,
    p: int = 2,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool = False,
):
    """Returns (dq, dk, dv) in the input dtypes."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    if hq % hkv:
        raise ValueError(f"Hq={hq} % Hkv={hkv} != 0")
    bh = b * hkv
    acc = jnp.promote_types(q.dtype, jnp.float32)

    cs = min(chunk_size, max(8, n))
    nc = -(-n // cs)
    pad = nc * cs - n
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, d).reshape(bh, g, nc * cs, d)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        bh, nc * cs, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        bh, nc * cs, dv)
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, dv).reshape(bh, g, nc * cs, dv)
    w = jnp.pad(jnp.ones((bh, n), acc), ((0, 0), (0, pad)))

    m0, m1, m2, g0, g1, g2 = state
    m2_rows = d * d if p >= 2 else 1
    fm0 = m0.reshape(bh, 1, dv).astype(acc)
    fm1 = m1.reshape(bh, d, dv).astype(acc)
    fm2 = (m2.reshape(bh, d * d, dv).astype(acc) if p >= 2
           else jnp.zeros((bh, 1, dv), acc))
    fg0 = g0.reshape(bh, 1, 1).astype(acc)
    fg1 = g1.reshape(bh, 1, d).astype(acc)
    fg2 = g2.reshape(bh, d, d).astype(acc)

    bm = pick_bm(d)
    kernel = functools.partial(_causal_bwd_kernel, p=p, bm=bm,
                               denom_eps=denom_eps, acc=acc)
    rev = lambda h, t: (h, nc - 1 - t, 0)       # noqa: E731 reverse chunks
    revq = lambda h, t: (h, 0, nc - 1 - t, 0)   # noqa: E731
    sm = lambda h, t: (h, 0, 0)                 # noqa: E731 constant blocks
    dq, dk, dvv = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, g, cs, d), revq),
            pl.BlockSpec((1, cs, d), rev),
            pl.BlockSpec((1, cs, dv), rev),
            pl.BlockSpec((1, cs), lambda h, t: (h, nc - 1 - t)),
            pl.BlockSpec((1, g, cs, dv), revq),
            pl.BlockSpec((1, 1, dv), sm),
            pl.BlockSpec((1, d, dv), sm),
            pl.BlockSpec((1, m2_rows, dv), sm),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ],
        out_specs=[
            pl.BlockSpec((1, g, cs, d), revq),
            pl.BlockSpec((1, cs, d), rev),
            pl.BlockSpec((1, cs, dv), rev),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, nc * cs, d), q.dtype),
            jax.ShapeDtypeStruct((bh, nc * cs, d), k.dtype),
            jax.ShapeDtypeStruct((bh, nc * cs, dv), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, dv), acc),
            pltpu.VMEM((d, dv), acc),
            pltpu.VMEM((m2_rows, dv), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
            pltpu.VMEM((1, dv), acc),
            pltpu.VMEM((d, dv), acc),
            pltpu.VMEM((m2_rows, dv), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
        name=f"fastmax_causal_bwd_p{p}",
    )(qp, kp, vp, w, dop, fm0, fm1, fm2, fg0, fg1, fg2)

    dq = dq.reshape(b, hkv, g, nc * cs, d)[:, :, :, :n].reshape(b, hq, n, d)
    dk = dk.reshape(b, hkv, nc * cs, d)[:, :, :n]
    dvv = dvv.reshape(b, hkv, nc * cs, dv)[:, :, :n]
    return dq, dk, dvv
