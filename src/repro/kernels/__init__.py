"""Pallas TPU kernels for the Fastmax hot paths (+ interpret-mode fallback).

fastmax_causal.py     — chunked prefix-scan causal attention (training fwd,
                        optionally emitting the final moment carry)
fastmax_causal_bwd.py — fused reversible-carry causal backward (paper §2.5)
fastmax_noncausal.py  — two-phase moments+combine (encoder / cross-attn)
fastmax_decode.py     — fused state-update + combine for serving
tiling.py             — shared m-block tiling policy
ops.py                — jit'd dispatchers; ref.py — pure-jnp oracle

`ops` is imported lazily so leaf modules (tiling) stay importable from
`repro.core` without a core <-> kernels import cycle.
"""
from __future__ import annotations

__all__ = ["ops"]


def __getattr__(name):
    if name == "ops":
        import importlib
        return importlib.import_module("repro.kernels.ops")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
