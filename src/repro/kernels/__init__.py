"""Pallas TPU kernels for the Fastmax hot paths (+ interpret-mode fallback).

fastmax_causal.py    — chunked prefix-scan causal attention (training)
fastmax_noncausal.py — two-phase moments+combine (encoder / cross-attn)
fastmax_decode.py    — fused state-update + combine for serving
ops.py               — jit'd dispatchers; ref.py — pure-jnp oracle
"""
from repro.kernels import ops  # noqa: F401
