"""Pallas TPU kernel: noncausal (bidirectional) Fastmax attention.

Two-phase schedule (DESIGN.md §2):

  Phase A (moments): grid (B·Hkv, MB, NC). For each m-block of the degree-2
    moment, stream the key/value chunks along the sequential NC axis and
    accumulate the [bm·D, Dv] moment tile resident in VMEM (output-revisiting
    pattern — index map constant along NC, so the tile is flushed once per
    m-block). Degree-0/1 moments + denominators accumulate only on the
    mb==0 pass.

  Phase B (combine): grid (B·Hkv, NQ, MB). Per query block, accumulate the
    φ₂(Q)·m2 contraction across m-blocks in an fp32 scratch accumulator and
    divide by the (m-block-independent) denominator on the last step.

Used for encoder / cross-attention (whisper, chameleon image-prefix) and for
noncausal LRA-style classification. Everything is MXU matmuls; VMEM per step
is O(C·D + bm·D·Dv) — independent of N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.fastmax_causal import _poly
from repro.kernels.tiling import pick_bm

__all__ = ["fastmax_noncausal_pallas"]


def _moment_kernel(k_ref, v_ref, w_ref,
                   m0_ref, m1_ref, m2_ref, g0_ref, g1_ref, g2_ref,
                   *, p, bm, acc):
    mb, c = pl.program_id(1), pl.program_id(2)
    cs, d = k_ref.shape[1], k_ref.shape[2]

    k = k_ref[0].astype(acc)
    v = v_ref[0].astype(acc)
    w = w_ref[0].astype(acc)
    kw = k * w[:, None]
    vw = v * w[:, None]

    @pl.when(jnp.logical_and(mb == 0, c == 0))
    def _init_small():
        m0_ref[...] = jnp.zeros_like(m0_ref)
        m1_ref[...] = jnp.zeros_like(m1_ref)
        g0_ref[...] = jnp.zeros_like(g0_ref)
        g1_ref[...] = jnp.zeros_like(g1_ref)
        if p >= 2:
            g2_ref[...] = jnp.zeros_like(g2_ref)

    @pl.when(mb == 0)
    def _small():
        m0_ref[0] += jnp.sum(vw, axis=0, keepdims=True)
        m1_ref[0] += jnp.dot(kw.T, v, preferred_element_type=acc)
        g0_ref[0] += jnp.sum(w).reshape(1, 1)
        g1_ref[0] += jnp.sum(kw, axis=0, keepdims=True)
        if p >= 2:
            g2_ref[0] += jnp.dot(kw.T, k, preferred_element_type=acc)

    if p >= 2:
        @pl.when(c == 0)
        def _init_m2():
            m2_ref[...] = jnp.zeros_like(m2_ref)

        km = jax.lax.dynamic_slice_in_dim(k, mb * bm, bm, 1)  # [C, bm]
        t = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
        m2_ref[0] += jnp.dot(t.T, vw, preferred_element_type=acc)


def _combine_kernel(q_ref, m0_ref, m1_ref, m2_ref, g0_ref, g1_ref, g2_ref,
                    o_ref, acc_s, den_s, *, p, bm, nmb, denom_eps, acc):
    mb = pl.program_id(2)
    g, cq, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = m1_ref.shape[2]
    q = q_ref[0].astype(acc).reshape(g * cq, d)

    @pl.when(mb == 0)
    def _deg01():
        num = jnp.broadcast_to(m0_ref[0], (g * cq, dv)) + jnp.dot(
            q, m1_ref[0], preferred_element_type=acc)
        den = g0_ref[0, 0, 0] + jnp.dot(q, g1_ref[0, 0],
                                        preferred_element_type=acc)
        if p >= 2:
            den = den + 0.5 * jnp.sum(
                jnp.dot(q, g2_ref[0], preferred_element_type=acc) * q,
                axis=-1)
        acc_s[...] = num
        den_s[...] = den[:, None]

    if p >= 2:
        qm = jax.lax.dynamic_slice_in_dim(q, mb * bm, bm, 1)
        y = (qm[:, :, None] * q[:, None, :]).reshape(g * cq, bm * d)
        acc_s[...] += 0.5 * jnp.dot(y, m2_ref[0],
                                    preferred_element_type=acc)

    @pl.when(mb == nmb - 1)
    def _emit():
        o = acc_s[...] / (den_s[...] + denom_eps)
        o_ref[0] = o.reshape(g, cq, dv).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("p", "chunk_size", "denom_eps", "interpret", "out_dtype",
                     "bm", "grid"),
)
def fastmax_noncausal_pallas(
    q: jnp.ndarray,  # [B, Hq, N, D]   (pre-normalized q̂)
    k: jnp.ndarray,  # [B, Hkv, M, D]  (pre-normalized k̂)
    v: jnp.ndarray,  # [B, Hkv, M, Dv]
    *,
    p: int = 2,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool = False,
    out_dtype=None,
    bm: int | None = None,
    grid: str | None = None,
) -> jnp.ndarray:
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    out_dtype = out_dtype or q.dtype

    cs = min(chunk_size, max(8, m))
    nkc = -(-m // cs)
    padk = nkc * cs - m
    cq = min(chunk_size, max(8, n))
    nqc = -(-n // cq)
    padq = nqc * cq - n

    acc = jnp.promote_types(q.dtype, jnp.float32)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, padk), (0, 0))).reshape(
        b * hkv, nkc * cs, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, padk), (0, 0))).reshape(
        b * hkv, nkc * cs, dv)
    w = jnp.pad(jnp.ones((b * hkv, m), acc), ((0, 0), (0, padk)))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, padq), (0, 0))).reshape(
        b, hkv, g, nqc * cq, d).reshape(b * hkv, g, nqc * cq, d)

    if bm is None:
        bm = pick_bm(d)
    if d % bm:
        raise ValueError(f"bm={bm} must divide D={d}")
    if grid is None:
        grid = "parallel"
    if grid not in ("parallel", "arbitrary"):
        raise ValueError(f"grid={grid!r}; expected 'parallel'|'arbitrary'")
    nmb = d // bm if p >= 2 else 1
    m2_rows = bm * d if p >= 2 else 1

    mom_kernel = functools.partial(_moment_kernel, p=p, bm=bm, acc=acc)
    m0, m1, m2, g0, g1, g2 = pl.pallas_call(
        mom_kernel,
        grid=(b * hkv, nmb, nkc),
        in_specs=[
            pl.BlockSpec((1, cs, d), lambda h, mb, c: (h, c, 0)),
            pl.BlockSpec((1, cs, dv), lambda h, mb, c: (h, c, 0)),
            pl.BlockSpec((1, cs), lambda h, mb, c: (h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dv), lambda h, mb, c: (h, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda h, mb, c: (h, 0, 0)),
            pl.BlockSpec((1, m2_rows, dv), lambda h, mb, c: (h, mb, 0)),
            pl.BlockSpec((1, 1, 1), lambda h, mb, c: (h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h, mb, c: (h, 0, 0)),
            pl.BlockSpec((1, d, d), lambda h, mb, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, 1, dv), acc),
            jax.ShapeDtypeStruct((b * hkv, d, dv), acc),
            jax.ShapeDtypeStruct((b * hkv, nmb * m2_rows, dv), acc),
            jax.ShapeDtypeStruct((b * hkv, 1, 1), acc),
            jax.ShapeDtypeStruct((b * hkv, 1, d), acc),
            jax.ShapeDtypeStruct((b * hkv, d, d), acc),
        ],
        compiler_params=tpu_compiler_params(
            (grid, "arbitrary", "arbitrary")),
        interpret=interpret,
        name=f"fastmax_moments_p{p}",
    )(kp, vp, w)

    comb_kernel = functools.partial(_combine_kernel, p=p, bm=bm, nmb=nmb,
                                    denom_eps=denom_eps, acc=acc)
    out = pl.pallas_call(
        comb_kernel,
        grid=(b * hkv, nqc, nmb),
        in_specs=[
            pl.BlockSpec((1, g, cq, d), lambda h, iq, mb: (h, 0, iq, 0)),
            pl.BlockSpec((1, 1, dv), lambda h, iq, mb: (h, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda h, iq, mb: (h, 0, 0)),
            pl.BlockSpec((1, m2_rows, dv), lambda h, iq, mb: (h, mb, 0)),
            pl.BlockSpec((1, 1, 1), lambda h, iq, mb: (h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h, iq, mb: (h, 0, 0)),
            pl.BlockSpec((1, d, d), lambda h, iq, mb: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, cq, dv), lambda h, iq, mb: (h, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, nqc * cq, dv), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((g * cq, dv), acc),
            pltpu.VMEM((g * cq, 1), acc),
        ],
        compiler_params=tpu_compiler_params((grid, grid, "arbitrary")),
        interpret=interpret,
        name=f"fastmax_combine_p{p}",
    )(qp, m0, m1, m2, g0, g1, g2)

    out = out.reshape(b, hkv, g, nqc * cq, dv)[:, :, :, :n]
    return out.reshape(b, hq, n, dv)
