"""shard_map wrappers: the fastmax/hybrid Pallas kernels on a mesh.

A `pallas_call` is opaque to the SPMD partitioner: under a mesh, GSPMD
treats it as a replicated computation and all-gathers every operand. These
wrappers make the kernels shard-native instead — each device runs the SAME
kernel body on its shard, with the partitioning chosen once per call site:

  heads mode    Hkv % tp == 0: batch over the DP axes ("pod","data"), kv
                heads (and their aligned query groups) over "model". Every
                kernel — forward, fused backward, decode — is embarrassingly
                parallel per (batch, kv-head), so the wrapped call has ZERO
                collectives; the only cross-device traffic left is the
                row-parallel wo psum the caller already does.
  seq mode      context parallelism for causal TRAINING: the sequence dim
                sharded over a "seq" mesh axis, each device running the
                full Pallas chunk scan on its contiguous token shard. The
                chunk fold is associative (the §2.5 reversible carry is
                built on it), so correctness needs exactly ONE constant-
                size collective per direction: forward, each device folds
                its local moments and receives the exclusive prefix sum of
                the earlier shards' moments (ppermute ring or allgather,
                picked by modeled bytes — `pick_cp_exchange`), seeding its
                kernel launch; backward, the fused kernel emits the
                cotangent of its seed (dC_i) and the suffix sum over later
                shards gives the gradient each shard's own moment delta
                receives — chained through `jax.vjp(compute_moments)`.
                Boundary traffic is O(D²·Dv) per device pair, independent
                of N — vs ring-attention's O(N·D) KV rotation
                (`cp_boundary_model` records both for the dryrun gate).
  feature mode  Hkv % tp != 0 (GQA/MQA at TP degree > Hkv) but Dv % tp == 0:
                moments and v sharded on the value-feature dim over "model"
                (the feature-TP layout of `_constrain_moments_j`), q/k and
                the scalar g-moments replicated across "model". Each device
                folds the token into ITS Dv-slice of (m0, m1, m2) and
                redundantly maintains the tiny g-moments, so the numerator
                splits tp-ways and the denominator is exact locally — zero
                collectives inside the inference wrappers (prefill forward
                + decode). TRAINING runs feature-TP too: the Dv-blocked
                fused backward decomposes additively over value-feature
                columns (every dq/dk term is linear in the block-local
                output cotangent and its denominator partial), so each
                device launches the blocked backward on its Dv shard and
                the wrapper psums the partial dq/dk ONCE per launch — the
                only collectives in the trainable path, off the per-chunk
                critical path (mathematically equal to psumming the score
                cotangent ds inside the chunk loop, without serializing a
                collective per chunk). The jnp chunked scan remains the
                REPRO_FASTMAX_BWD=jnp oracle (`attention/backends.py`).

The group alignment heads mode relies on: q heads are grouped contiguously
([B, Hkv, G, ...] reshape), so a "model" shard of Hq = G·Hkv heads is
exactly the query groups of its Hkv-shard — no regrouping traffic.

`plan_kernel_sharding` returns None when neither mode divides (the caller
falls back to the jnp feature-TP moment step, logged), and
`nontrivial_mesh()` distinguishes "no mesh at all" (plain single-device
kernel call) from "mesh but unpartitionable".
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple, Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["ShardPlan", "nontrivial_mesh", "plan_kernel_sharding",
           "fastmax_sharded", "fastmax_prefill_sharded",
           "fastmax_decode_sharded", "hybrid_sharded", "pick_cp_exchange",
           "cp_carry_bytes", "cp_boundary_model"]


class ShardPlan(NamedTuple):
    """How one fastmax kernel call partitions over the active mesh."""

    mesh: object            # jax.sharding.Mesh
    batch: object           # P entry for the batch dim: None | axis | tuple
    mode: str               # "heads" | "feature" | "seq"
    tp: int                 # size of the "model" axis (1 = no TP)
    cp: int = 1             # size of the "seq" axis (1 = no CP)

    @property
    def head(self):
        return "model" if (self.mode == "heads" and self.tp > 1) else None

    @property
    def feat(self):
        return "model" if self.mode == "feature" else None

    def describe(self) -> str:
        mesh_s = "x".join(f"{a}={self.mesh.shape[a]}"
                          for a in self.mesh.axis_names)
        return f"shard_map[{self.mode}] over ({mesh_s})"


def nontrivial_mesh():
    """The active mesh when any axis has size > 1, else None."""
    from repro.sharding.rules import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return None
    if all(mesh.shape[a] == 1 for a in mesh.axis_names):
        return None
    return mesh


def plan_kernel_sharding(mesh, *, batch: int, hq: int, hkv: int,
                         dv: int, seq_len: int | None = None,
                         ) -> Optional[ShardPlan]:
    """Pick the partitioning for a fastmax kernel call, or None.

    None means the mesh tensor-parallelizes over "model" but neither kv
    heads nor the value-feature dim divide it — the caller should use the
    jnp moment path, whose with_sharding_constraint layout degrades
    gracefully per dim. Any other mesh gets a plan, possibly degenerate
    (no 'model' axis, batch indivisible -> an all-replicated wrap), so the
    kernels stay the path whenever they CAN run.

    `seq_len` opts into seq mode (context parallelism): callers pass it
    only for causal TRAINING-shaped calls on a mesh with a "seq" axis of
    size > 1 dividing it. CP×TP composition is deferred: with tp > 1 the
    head/feature modes win and the seq axis is simply unused (replicated —
    still correct, just not context-parallel). Decode/prefill callers
    never pass seq_len, so under a pure-CP mesh they get the degenerate
    heads plan and the kernels stay the path.
    """
    if mesh is None:
        return None
    from repro.sharding.rules import _batch_entry

    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    cp = mesh.shape["seq"] if "seq" in mesh.axis_names else 1
    b_entry, _ = _batch_entry(mesh, batch)
    if tp > 1:
        if hkv % tp == 0 and hq % tp == 0:
            mode = "heads"
        elif dv % tp == 0:
            mode = "feature"
        else:
            return None
    elif cp > 1 and seq_len is not None and seq_len % cp == 0:
        mode = "seq"
        return ShardPlan(mesh=mesh, batch=b_entry, mode=mode, tp=tp, cp=cp)
    else:
        mode = "heads"   # degenerate: DP-only wrap, heads unsharded
    return ShardPlan(mesh=mesh, batch=b_entry, mode=mode, tp=tp)


def _moment_specs(plan: ShardPlan):
    """In/out PartitionSpecs of a Moments-layout tuple [B,Hkv,...]."""
    ba, h, f = plan.batch, plan.head, plan.feat
    return (
        P(ba, h, f),                    # m0 [B,Hkv,Dv]
        P(ba, h, None, f),              # m1 [B,Hkv,D,Dv]
        P(ba, h, None, None, f),        # m2 [B,Hkv,D,D,Dv]
        P(ba, h),                       # g0 [B,Hkv]
        P(ba, h, None),                 # g1 [B,Hkv,D]
        P(ba, h, None, None),           # g2 [B,Hkv,D,D]
    )


# ---------------------------------------------------------------------------
# Context parallelism (seq mode)
# ---------------------------------------------------------------------------

# temp-memory budget for the allgather exchange: gathering cp carries
# materializes cp × carry_bytes per device; past this, take the ring's
# cp-1 sequential constant-size hops instead
_CP_ALLGATHER_BUDGET = 256 * 1024 * 1024


def cp_carry_bytes(*, b: int, hkv: int, d: int, dv: int, p: int,
                   itemsize: int = 4) -> int:
    """Bytes of ONE device's exchanged moment carry (the per-boundary
    payload). m2/g2 exist only at p >= 2 — at p = 1 they are zeros the
    exchange skips."""
    elems = dv + d * dv + 1 + d
    if p >= 2:
        elems += d * d * dv + d * d
    return b * hkv * elems * itemsize


def pick_cp_exchange(cp: int, carry_bytes: int) -> str:
    """'allgather' (one collective, cp·carry_bytes temp) under the budget,
    else 'ring' (cp-1 ppermute hops, constant memory). REPRO_CP_EXCHANGE
    overrides: auto|ring|allgather (the two differ in summation ORDER, so
    tests compare them under allclose, not bitwise)."""
    forced = os.environ.get("REPRO_CP_EXCHANGE", "auto").lower()
    if forced in ("ring", "allgather"):
        return forced
    return "allgather" if cp * carry_bytes <= _CP_ALLGATHER_BUDGET else "ring"


def cp_boundary_model(*, n: int, b: int, hkv: int, d: int, dv: int, p: int,
                      cp: int, itemsize: int = 4) -> dict:
    """Modeled per-boundary collective bytes: the CP carry exchange vs the
    ring-attention alternative (each boundary step rotates a neighbor's
    K/V shard of n/cp tokens — O(N·D), growing with sequence length; the
    moment carry is O(D²·Dv), independent of N). Recorded in the dryrun
    cell JSON so the gate can assert N-independence."""
    carry = cp_carry_bytes(b=b, hkv=hkv, d=d, dv=dv, p=p, itemsize=itemsize)
    ring_attn = b * hkv * (n // max(cp, 1)) * (d + dv) * itemsize
    return {
        "cp": cp,
        "exchange": pick_cp_exchange(cp, carry),
        "carry_bytes_per_boundary": carry,
        "ring_attention_bytes_per_boundary": ring_attn,
        "carry_to_ring_ratio": carry / ring_attn if ring_attn else None,
    }


def _cp_prefix_sum(leaves: tuple, cp: int, impl: str, reverse: bool = False):
    """EXCLUSIVE prefix (Σ_{j<i}; reverse=True: suffix Σ_{j>i}) sum of
    per-device arrays over the "seq" axis. Runs inside a shard_map body.

    allgather: one collective + a masked contraction. ring: cp-1
    sequential ppermute hops — after s hops device i holds shard i∓s's
    leaves and folds them iff that shard is on the correct side (no
    wraparound contribution is ever included)."""
    import jax.numpy as jnp

    idx = jax.lax.axis_index("seq")
    if impl == "allgather":
        ar = jnp.arange(cp)
        sel = (ar > idx) if reverse else (ar < idx)

        def one(x):
            g = jax.lax.all_gather(x, "seq")             # [cp, ...]
            return jnp.tensordot(sel.astype(g.dtype), g, axes=1)

        return tuple(one(x) for x in leaves)
    shift = -1 if reverse else 1
    perm = [(j, (j + shift) % cp) for j in range(cp)]
    acc = tuple(jnp.zeros_like(x) for x in leaves)
    msg = leaves
    for s in range(1, cp):
        msg = tuple(jax.lax.ppermute(x, "seq", perm) for x in msg)
        take = (idx < cp - s) if reverse else (idx >= s)
        acc = tuple(a + jnp.where(take, m, jnp.zeros_like(m))
                    for a, m in zip(acc, msg))
    return acc


def _seq_state_specs(ba):
    """Specs of the stacked per-shard carry [cp(seq), B, Hkv, ...] — each
    shard's final moments differ, so the residual keeps them under a
    leading "seq"-sharded axis instead of pretending replication."""
    return (
        P("seq", ba, None, None),                # m0 [cp,B,Hkv,Dv]
        P("seq", ba, None, None, None),          # m1
        P("seq", ba, None, None, None, None),    # m2
        P("seq", ba, None),                      # g0
        P("seq", ba, None, None),                # g1
        P("seq", ba, None, None, None),          # g2
    )


def _seq_fwd_launch(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    """Seq-mode forward: (o, stacked per-shard final carries).

    Per device: fold the local shard's moments (jnp chunked fold — same
    flop order as the kernel's, memory-bounded), ONE exclusive-prefix
    exchange of the constant-size carry, then a single seeded Pallas
    launch whose outputs are the exact causal outputs of the full
    sequence restricted to this shard.
    """
    import jax.numpy as jnp

    from repro.core.fastmax import compute_moments_chunked
    from repro.kernels import ops as kernel_ops

    b, hq, n, d = q.shape
    hkv, dv = k.shape[1], v.shape[-1]
    ba, cp = plan.batch, plan.cp
    impl = pick_cp_exchange(
        cp, cp_carry_bytes(b=b, hkv=hkv, d=d, dv=dv, p=p))
    shard4 = P(ba, None, "seq", None)

    def body(q, k, v):
        mom = compute_moments_chunked(k, v, p=p, chunk_size=chunk_size)
        live = tuple(mom) if p >= 2 else (mom[0], mom[1], mom[3], mom[4])
        carry = _cp_prefix_sum(live, cp, impl)
        if p < 2:
            carry = (carry[0], carry[1], jnp.zeros_like(mom[2]),
                     carry[2], carry[3], jnp.zeros_like(mom[5]))
        o, state = kernel_ops.fastmax_prefill_kernel(
            q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
            schedule=schedule, init_state=carry)
        return o, tuple(x[None] for x in state)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(shard4, shard4, shard4),
        out_specs=(shard4, _seq_state_specs(ba)),
        check_rep=False,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _seq_trainable(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    o, _ = _seq_fwd_launch(q, k, v, p, chunk_size, denom_eps, plan,
                           schedule)
    return o


def _st_fwd(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    o, state = _seq_fwd_launch(q, k, v, p, chunk_size, denom_eps, plan,
                               schedule)
    if p < 2:
        # don't hold the [cp,B,Hkv,D,D,Dv] zeros placeholder as a residual
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, tuple(state))


def _st_bwd(p, chunk_size, denom_eps, plan, schedule, res, do):
    q, k, v, state = res
    from repro.core.fastmax import compute_moments_chunked
    from repro.kernels import ops as kernel_ops

    b, hq, n, d = q.shape
    hkv, dv = k.shape[1], v.shape[-1]
    ba, cp = plan.batch, plan.cp
    impl = pick_cp_exchange(
        cp, cp_carry_bytes(b=b, hkv=hkv, d=d, dv=dv, p=p))
    shard4 = P(ba, None, "seq", None)
    sspecs = _seq_state_specs(ba)
    no_m2 = state[2] is None
    if no_m2:
        state, sspecs = state[:2] + state[3:], sspecs[:2] + sspecs[3:]

    def body(q, k, v, do, *state):
        import jax.numpy as jnp

        state = tuple(x[0] for x in state)      # strip the stacked seq lead
        if no_m2:
            state = state[:2] + (None,) + state[2:]
        # local fused backward on the SEEDED forward's final carry: the
        # reversible subtraction reconstructs down to the seed, so dq/dk/dv
        # are this shard's exact local grads and dC the seed's cotangent
        dq, dk, dvv, dC = kernel_ops.fastmax_bwd(
            q, k, v, state, do, p=p, chunk_size=chunk_size,
            denom_eps=denom_eps, schedule=schedule, return_dstate=True)
        # one suffix exchange: later shards' seeds contain THIS shard's
        # moment delta, so Σ_{j>i} dC_j is the gradient it receives
        live = (tuple(dC) if p >= 2
                else (dC[0], dC[1], dC[3], dC[4]))
        dM = _cp_prefix_sum(live, cp, impl, reverse=True)

        def moments_fn(kk, vv):
            mom = compute_moments_chunked(kk, vv, p=p,
                                          chunk_size=chunk_size)
            return (tuple(mom) if p >= 2
                    else (mom[0], mom[1], mom[3], mom[4]))

        prim, vjp_fn = jax.vjp(moments_fn, k, v)
        dM = tuple(x.astype(y.dtype) for x, y in zip(dM, prim))
        dk_x, dv_x = vjp_fn(dM)
        acc = jnp.promote_types(q.dtype, jnp.float32)
        dk = (dk.astype(acc) + dk_x.astype(acc)).astype(k.dtype)
        dvv = (dvv.astype(acc) + dv_x.astype(acc)).astype(v.dtype)
        return dq, dk, dvv

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(shard4, shard4, shard4, shard4, *sspecs),
        out_specs=(shard4, shard4, shard4),
        check_rep=False,
    )(q, k, v, do, *state)


_seq_trainable.defvjp(_st_fwd, _st_bwd)


def fastmax_sharded(q, k, v, *, p: int, causal: bool, chunk_size: int,
                    denom_eps: float, plan: ShardPlan, schedule=None):
    """shard_map-wrapped TRAINABLE kernel attention.

    heads mode: autodiff of the shard_map applies the per-shard custom_vjp,
    so the fused Pallas backward runs shard-local per (batch, kv-head) with
    zero collectives. feature mode (causal only): the Dv-blocked kernels
    run per value-feature shard through an explicit custom_vjp — forward
    emits the Dv-sharded outputs + moment carry collective-free, backward
    launches the blocked kernel on each shard's (v, do, m-moments) slice
    and psums the partial dq/dk once per launch (see module docstring).

    `schedule` (an `autotune.Schedule` or None) forces one schedule on
    every per-shard launch; None lets the in-body autotune lookup key on
    the SHARD-LOCAL shapes — the ones the per-device kernels actually run.
    """
    if plan.mode == "heads":
        from repro.kernels import ops as kernel_ops

        ba, h = plan.batch, plan.head
        qkv_spec = P(ba, h, None, None)

        def body(q, k, v):
            return kernel_ops.fastmax(q, k, v, p=p, causal=causal,
                                      chunk_size=chunk_size,
                                      denom_eps=denom_eps,
                                      schedule=schedule)

        return shard_map(
            body, mesh=plan.mesh,
            in_specs=(qkv_spec, qkv_spec, qkv_spec),
            out_specs=P(ba, h, None, None),
            check_rep=False,
        )(q, k, v)
    if plan.mode == "seq":
        if not causal:
            raise ValueError(
                "seq-mode (context-parallel) shard_map is causal-only")
        return _seq_trainable(q, k, v, p, chunk_size, denom_eps, plan,
                              schedule)
    if not causal:
        # feature mode, noncausal: shard_map wrap of the two-phase
        # noncausal kernel. The global moments are Dv-decomposable and its
        # denominator comes from the replicated k, so each device's launch
        # on its (q, k, v-slice) yields the exact Dv slice of the output
        # with zero collectives. Training works through plain autodiff of
        # this wrap: the op pairs the kernel forward with the jnp moment
        # backward (`ops._fastmax_noncausal_trainable`), each shard's
        # dq/dk are exact partials over its Dv columns, and shard_map's
        # transpose psums the replicated inputs' cotangents.
        from repro.kernels import ops as kernel_ops

        ba, f = plan.batch, plan.feat
        rep4 = P(ba, None, None, None)

        def nc_body(q, k, v):
            return kernel_ops.fastmax(q, k, v, p=p, causal=False,
                                      chunk_size=chunk_size,
                                      denom_eps=denom_eps,
                                      schedule=schedule)

        return shard_map(
            nc_body, mesh=plan.mesh,
            in_specs=(rep4, rep4, P(ba, None, None, f)),
            out_specs=P(ba, None, None, f),
            check_rep=False,
        )(q, k, v)
    return _feature_trainable(q, k, v, p, chunk_size, denom_eps, plan,
                              schedule)


def _feature_fwd_launch(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    """Forward launch of the feature-mode trainable: (o, final carry).

    One shard_map of the state-emitting causal kernel: v and the emitted
    m-moments/outputs Dv-sharded, q/k and the g-moments replicated — the
    same zero-collective partitioning as `fastmax_prefill_sharded`, reused
    here so the custom_vjp residual is the kernel-emitted carry (no second
    pass) already in the layout the per-shard backward consumes.
    """
    from repro.kernels import ops as kernel_ops

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)

    def body(q, k, v):
        return kernel_ops.fastmax_prefill_kernel(
            q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
            schedule=schedule)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f)),
        out_specs=(P(ba, None, None, f), _moment_specs(plan)),
        check_rep=False,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _feature_trainable(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    # primal (non-differentiated calls): the STATELESS kernel — no carry
    # DMA'd to HBM and the forward's nb grid axis stays parallel; only the
    # vjp forward below pays for state emission (it IS the residual)
    from repro.kernels import ops as kernel_ops

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)

    def body(q, k, v):
        return kernel_ops.fastmax(q, k, v, p=p, causal=True,
                                  chunk_size=chunk_size,
                                  denom_eps=denom_eps, schedule=schedule)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f)),
        out_specs=P(ba, None, None, f),
        check_rep=False,
    )(q, k, v)


def _ft_fwd(q, k, v, p, chunk_size, denom_eps, plan, schedule):
    o, state = _feature_fwd_launch(q, k, v, p, chunk_size, denom_eps, plan,
                                   schedule)
    if p < 2:
        # don't hold the [B,Hkv,D,D,Dv] zeros placeholder live as a residual
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, tuple(state))


def _ft_bwd(p, chunk_size, denom_eps, plan, schedule, res, do):
    q, k, v, state = res
    from repro.kernels import ops as kernel_ops

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)
    mspecs = _moment_specs(plan)
    # p < 2: the residual dropped the m2 zeros placeholder — don't rebuild
    # it at global size just to shard it in; pass the 5 live leaves and let
    # fastmax_bwd handle the None (the Pallas kernel never reads m2 at
    # p < 2, the jnp-oracle branch rebuilds shard-local zeros itself)
    no_m2 = state[2] is None
    if no_m2:
        state, mspecs = state[:2] + state[3:], mspecs[:2] + mspecs[3:]

    def body(q, k, v, do, *state):
        if no_m2:
            state = state[:2] + (None,) + state[2:]
        # the local launch sees the shard's Dv slice of (v, do, m-moments)
        # and the full g-moments: its dq/dk are the shard's exact partials
        # (fastmax_bwd docstring), its dv the shard's exact slice
        dq, dk, dv = kernel_ops.fastmax_bwd(
            q, k, v, tuple(state), do, p=p, chunk_size=chunk_size,
            denom_eps=denom_eps, schedule=schedule)
        dq = jax.lax.psum(dq, "model")
        dk = jax.lax.psum(dk, "model")
        return dq, dk, dv

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f), P(ba, None, None, f),
                  *mspecs),
        out_specs=(rep4, rep4, P(ba, None, None, f)),
        check_rep=False,
    )(q, k, v, do, *state)


_feature_trainable.defvjp(_ft_fwd, _ft_bwd)


# ---------------------------------------------------------------------------
# Hybrid near/far-field (banded softmax + moments) — heads/feature modes
# ---------------------------------------------------------------------------


def _hybrid_sched(q, k, v, p, chunk_size, schedule):
    """Shard-local schedule for a hybrid launch + the chunk size its jnp
    backward must re-chunk with (w_eff depends on the chunk length, so
    forward and backward are pinned to ONE chunk size — deterministic
    lookup keeps the vjp-fwd and vjp-bwd bodies consistent)."""
    from repro.kernels import ops as kernel_ops

    sched = schedule if schedule is not None else kernel_ops._lookup(
        "hybrid_fwd", q, k, v, p, chunk_size)
    return sched, (sched.chunk_size if sched is not None else chunk_size)


def _hybrid_feature_fwd_launch(q, k, v, p, window, chunk_size, denom_eps,
                               plan, schedule):
    """Feature-mode hybrid forward: (o, final moment carry), both
    Dv-sharded; q/k and the g-moments replicated — the same
    zero-collective partitioning as `_feature_fwd_launch` (the band's
    denominator terms come entirely from the replicated q/k, so each
    device's output slice is exact)."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels.hybrid_causal import hybrid_causal_pallas

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)
    interpret = kernel_ops.use_interpret()

    def body(q, k, v):
        sched, _ = _hybrid_sched(q, k, v, p, chunk_size, schedule)
        return hybrid_causal_pallas(
            q, k, v, p=p, window=window, denom_eps=denom_eps,
            interpret=interpret, return_state=True,
            **kernel_ops._causal_kwargs(sched, chunk_size))

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f)),
        out_specs=(P(ba, None, None, f), _moment_specs(plan)),
        check_rep=False,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _hybrid_feature_trainable(q, k, v, p, window, chunk_size, denom_eps,
                              plan, schedule):
    # primal: the stateless fused launch (no carry DMA'd to HBM); only the
    # vjp forward pays for state emission — it IS the residual
    from repro.kernels import ops as kernel_ops

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)

    def body(q, k, v):
        return kernel_ops.hybrid(q, k, v, p=p, window=window, causal=True,
                                 chunk_size=chunk_size, denom_eps=denom_eps,
                                 schedule=schedule)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f)),
        out_specs=P(ba, None, None, f),
        check_rep=False,
    )(q, k, v)


def _hft_fwd(q, k, v, p, window, chunk_size, denom_eps, plan, schedule):
    o, state = _hybrid_feature_fwd_launch(q, k, v, p, window, chunk_size,
                                          denom_eps, plan, schedule)
    if p < 2:
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, tuple(state))


def _hft_bwd(p, window, chunk_size, denom_eps, plan, schedule, res, do):
    q, k, v, state = res
    from repro.core import fastmax as _fm
    from repro.core.hybrid import hybrid_bwd_scan

    ba, f = plan.batch, plan.feat
    rep4 = P(ba, None, None, None)
    mspecs = _moment_specs(plan)
    no_m2 = state[2] is None
    if no_m2:
        state, mspecs = state[:2] + state[3:], mspecs[:2] + mspecs[3:]

    def body(q, k, v, do, *state):
        import jax.numpy as jnp

        if no_m2:
            d, dvl = q.shape[-1], v.shape[-1]
            m2 = jnp.zeros(k.shape[:2] + (d, d, dvl), state[0].dtype)
            state = state[:2] + (m2,) + state[2:]
        # the band-extended §2.5 reverse scan on the shard's Dv slice of
        # (v, do, m-moments): every dq/dk term (band corrections included)
        # is linear in the block-local output cotangent with an exact
        # local denominator, so one psum per launch reassembles them
        _, cs = _hybrid_sched(q, k, v, p, chunk_size, schedule)
        dq, dk, dv = hybrid_bwd_scan(
            q, k, v, _fm.Moments(*state), do, p=p, window=window,
            chunk_size=cs, denom_eps=denom_eps)
        dq = jax.lax.psum(dq, "model")
        dk = jax.lax.psum(dk, "model")
        return dq, dk, dv

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(rep4, rep4, P(ba, None, None, f), P(ba, None, None, f),
                  *mspecs),
        out_specs=(rep4, rep4, P(ba, None, None, f)),
        check_rep=False,
    )(q, k, v, do, *state)


_hybrid_feature_trainable.defvjp(_hft_fwd, _hft_bwd)


def hybrid_sharded(q, k, v, *, p: int, window: int, chunk_size: int,
                   denom_eps: float, plan: ShardPlan, schedule=None):
    """shard_map-wrapped TRAINABLE hybrid kernel attention (causal only).

    heads mode: the fused hybrid launch runs shard-local per (batch,
    kv-head) — autodiff of the shard_map applies the per-shard custom_vjp
    (fused forward + jnp band-extended reverse scan), zero collectives.
    feature mode: an explicit custom_vjp mirroring `_feature_trainable` —
    forward emits the Dv-sharded outputs + moment carry collective-free,
    backward runs the band-extended jnp reverse scan on each shard's
    slice and psums the partial dq/dk once per launch.
    """
    if plan.mode == "heads":
        from repro.kernels import ops as kernel_ops

        ba, h = plan.batch, plan.head
        qkv_spec = P(ba, h, None, None)

        def body(q, k, v):
            return kernel_ops.hybrid(q, k, v, p=p, window=window,
                                     causal=True, chunk_size=chunk_size,
                                     denom_eps=denom_eps, schedule=schedule)

        return shard_map(
            body, mesh=plan.mesh,
            in_specs=(qkv_spec, qkv_spec, qkv_spec),
            out_specs=P(ba, h, None, None),
            check_rep=False,
        )(q, k, v)
    if plan.mode != "feature":
        raise ValueError(
            f"hybrid_sharded supports heads/feature modes, got "
            f"{plan.mode!r}")
    return _hybrid_feature_trainable(q, k, v, p, window, chunk_size,
                                     denom_eps, plan, schedule)


def fastmax_prefill_sharded(q, k, v, *, p: int, chunk_size: int,
                            denom_eps: float, kv_mask=None,
                            plan: ShardPlan, schedule=None):
    """shard_map-wrapped causal prefill kernel: (o, final moment tuple).

    heads mode: everything head-local. feature mode: v and the m-moments
    live on Dv-slices; q/k/g-moments are replicated over "model" (each
    device maintains the identical tiny g state), so the launch is
    collective-free and the outputs come back Dv-sharded — exactly the
    layout `decode_state_shardings` commits between steps.
    """
    import jax.numpy as jnp

    from repro.kernels import ops as kernel_ops

    ba, h, f = plan.batch, plan.head, plan.feat
    in_specs = [P(ba, h, None, None),    # q
                P(ba, h, None, None),    # k
                P(ba, h, None, f)]       # v
    args = [q, k, v]
    if kv_mask is not None:
        if h is not None and kv_mask.shape[1] == 1:
            kv_mask = jnp.broadcast_to(
                kv_mask, (kv_mask.shape[0], k.shape[1], kv_mask.shape[2]))
        in_specs.append(P(ba, h, None))
        args.append(kv_mask)

    def body(q, k, v, *rest):
        mask = rest[0] if rest else None
        return kernel_ops.fastmax_prefill_kernel(
            q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
            kv_mask=mask, schedule=schedule)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(ba, h, None, f), _moment_specs(plan)),
        check_rep=False,
    )(*args)


def fastmax_decode_sharded(q, k, v, state, *, p: int, denom_eps: float,
                           plan: ShardPlan, schedule=None):
    """shard_map-wrapped fused decode step: (o, new moment tuple).

    The serving hot loop at TP > 1: per step each device streams only ITS
    moment shard (1/tp of m2 in feature mode; its heads in heads mode) —
    the HBM traffic the fused kernel exists to minimize now also splits
    tp-ways, with no collectives inside the step.
    """
    from repro.kernels import ops as kernel_ops

    ba, h, f = plan.batch, plan.head, plan.feat
    mspecs = _moment_specs(plan)

    def body(q, k, v, *state):
        return kernel_ops.fastmax_decode(q, k, v, tuple(state), p=p,
                                         denom_eps=denom_eps,
                                         schedule=schedule)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(ba, h, None, None),   # q
                  P(ba, h, None, None),   # k
                  P(ba, h, None, f),      # v
                  *mspecs),
        out_specs=(P(ba, h, None, f), mspecs),
        check_rep=False,
    )(q, k, v, *tuple(state))
