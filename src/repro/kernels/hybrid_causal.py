"""Pallas TPU kernel: hybrid near/far-field causal attention.

One launch per chunk, same chunked prefix-scan schedule as
`fastmax_causal.py` — the far field is the identical reversible moment
carry (VMEM scratch, m-major degree-2 block, Dv column blocks) — plus
the near field: an exact (exp - f_p) correction over the width-w causal
band, computed from the score blocks the scan already touches. Because
the effective band is clamped to one chunk (w_eff = min(window, C)), the
band only ever reaches the CURRENT chunk's keys and the PREVIOUS
chunk's, so the kernel adds exactly two extra inputs: the previous
chunk's (k, v, validity) blocks, selected by an index map at c-1 and
nulled at c == 0.

The correction form keeps the moment leg untouched: the band adds
(exp(s) - f_p(s)) on top of the f_p(s) the intra-chunk/moment paths
already contribute, so numerator and denominator stay one sum and w=0
reproduces fastmax exactly.

Forward-only (+ emitted final carry): the trainable path's backward is
the jnp §2.5 reverse scan extended with band residuals
(`repro.core.hybrid.hybrid_bwd_scan`), seeded by this kernel's emitted
state — see `kernels/ops.hybrid`.

Validated against `repro.core.hybrid.hybrid_attention_ref` in interpret
mode (tests/test_hybrid.py) in f64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.tiling import FWD_BLK_BUDGET, pick_blk, pick_bm

__all__ = ["hybrid_causal_pallas"]


def _poly(s, p):
    out = 1.0 + s
    if p >= 2:
        out = out + 0.5 * s * s
    return out


def _hybrid_kernel(
    q_ref,    # [1, G, C, D]
    k_ref,    # [1, C, D]
    v_ref,    # [1, C, Dv-block]
    w_ref,    # [1, C]       validity mask (1=real token, 0=padding)
    kp_ref,   # [1, C, D]    previous chunk's keys   (block c-1; junk at c=0)
    vp_ref,   # [1, C, Dv-block] previous chunk's values
    wp_ref,   # [1, C]       previous chunk's validity
    *refs,    # o_ref + [state outputs (emit_state)] + 6 moment scratch
    p: int,
    bm: int,
    w_eff: int,
    denom_eps: float,
    acc,
    emit_state: bool,
):
    o_ref = refs[0]
    refs = refs[1:]
    if emit_state:
        (m0o, m1o, m2o, g0o, g1o, g2o) = refs[:6]
        refs = refs[6:]
    m0_s, m1_s, m2_s, g0_s, g1_s, g2_s = refs
    c = pl.program_id(2)
    nc = pl.num_programs(2)
    g, cs, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = v_ref.shape[2]

    f32 = acc
    @pl.when(c == 0)
    def _init():
        m0_s[...] = jnp.zeros_like(m0_s)
        m1_s[...] = jnp.zeros_like(m1_s)
        g0_s[...] = jnp.zeros_like(g0_s)
        g1_s[...] = jnp.zeros_like(g1_s)
        if p >= 2:
            m2_s[...] = jnp.zeros_like(m2_s)
            g2_s[...] = jnp.zeros_like(g2_s)

    q = q_ref[0].astype(f32).reshape(g * cs, d)   # [GC, D]
    k = k_ref[0].astype(f32)                      # [C, D]
    v = v_ref[0].astype(f32)                      # [C, Dv]
    w = w_ref[0].astype(f32)                      # [C]

    # ---- far field: contract carry (strictly-previous chunks) with q ----
    num = jnp.broadcast_to(m0_s[...], (g * cs, dv)) + jnp.dot(
        q, m1_s[...], preferred_element_type=f32
    )
    den = g0_s[0, 0] + jnp.dot(q, g1_s[0], preferred_element_type=f32)
    if p >= 2:
        den = den + 0.5 * jnp.sum(
            jnp.dot(q, g2_s[...], preferred_element_type=f32) * q,
            axis=-1,
        )

        def mb_step(i, acc_):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)  # [GC, bm]
            y = (qm[:, :, None] * q[:, None, :]).reshape(g * cs, bm * d)
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]      # [bm*D, Dv]
            return acc_ + jnp.dot(y, z, preferred_element_type=f32)

        num = num + 0.5 * jax.lax.fori_loop(
            0, d // bm, mb_step, jnp.zeros((g * cs, dv), f32)
        )

    # ---- intra-chunk: exact causal block through f(QK^T) ----
    s = jnp.dot(q, k.T, preferred_element_type=f32)  # [GC, C]
    fs = _poly(s, p)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (g * cs, cs), 0) % cs
    kpos = jax.lax.broadcasted_iota(jnp.int32, (g * cs, cs), 1)
    fs = jnp.where(qpos >= kpos, fs, 0.0) * w[None, :]
    num = num + jnp.dot(fs, v, preferred_element_type=f32)
    den = den + jnp.sum(fs, axis=-1)

    # ---- near field: (exp - f_p) over the width-w_eff causal band ----
    if w_eff > 0:
        intra = (qpos >= kpos) & (qpos - kpos < w_eff)
        corr = jnp.where(intra, jnp.exp(s) - _poly(s, p), 0.0) * w[None, :]
        num = num + jnp.dot(corr, v, preferred_element_type=f32)
        den = den + jnp.sum(corr, axis=-1)
        # previous chunk's keys: distance = qpos + C - kpos, gated at c==0
        kprev = kp_ref[0].astype(f32)
        vprev = vp_ref[0].astype(f32)
        wprev = wp_ref[0].astype(f32) * jnp.where(c > 0, 1.0, 0.0)
        sp = jnp.dot(q, kprev.T, preferred_element_type=f32)
        pband = (qpos + cs - kpos) < w_eff
        corr_p = jnp.where(pband, jnp.exp(sp) - _poly(sp, p), 0.0)
        corr_p = corr_p * wprev[None, :]
        num = num + jnp.dot(corr_p, vprev, preferred_element_type=f32)
        den = den + jnp.sum(corr_p, axis=-1)

    o = num / (den + denom_eps)[:, None]
    o_ref[0] = o.reshape(g, cs, dv).astype(o_ref.dtype)

    # ---- fold this chunk into the carry ----
    kw = k * w[:, None]
    vw = v * w[:, None]
    m0_s[...] += jnp.sum(vw, axis=0, keepdims=True)
    m1_s[...] += jnp.dot(kw.T, v, preferred_element_type=f32)
    g0_s[...] += jnp.sum(w).reshape(1, 1)
    g1_s[...] += jnp.sum(kw, axis=0, keepdims=True)
    if p >= 2:
        g2_s[...] += jnp.dot(kw.T, k, preferred_element_type=f32)

        def mb_up(i, _):
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)  # [C, bm]
            t = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            m2_s[pl.dslice(i * bm * d, bm * d), :] += jnp.dot(
                t.T, vw, preferred_element_type=f32
            )
            return 0

        jax.lax.fori_loop(0, d // bm, mb_up, 0)

    if emit_state:
        @pl.when(c == nc - 1)
        def _emit_state():
            m0o[0] = m0_s[...]
            m1o[0] = m1_s[...]
            g0o[0] = g0_s[...]
            g1o[0] = g1_s[...]
            if p >= 2:
                m2o[0] = m2_s[...]
                g2o[0] = g2_s[...]
            else:
                m2o[0] = jnp.zeros_like(m2o[0])
                g2o[0] = jnp.zeros_like(g2o[0])


@functools.partial(
    jax.jit,
    static_argnames=("p", "window", "chunk_size", "denom_eps", "interpret",
                     "out_dtype", "return_state", "blk", "bm", "grid"),
)
def hybrid_causal_pallas(
    q: jnp.ndarray,  # [B, Hq, N, D]  (pre-normalized q̂)
    k: jnp.ndarray,  # [B, Hkv, N, D] (pre-normalized k̂)
    v: jnp.ndarray,  # [B, Hkv, N, Dv]
    kv_mask: jnp.ndarray | None = None,  # [B, Hkv|1, N] validity (1=real)
    *,
    p: int = 2,
    window: int = 64,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool = False,
    out_dtype=None,
    return_state: bool = False,
    blk: int | None = None,
    bm: int | None = None,
    grid: str | None = None,
):
    """Hybrid causal forward. `window` is clamped to the chunk
    (w_eff = min(window, C)); at w_eff == 0 this IS fastmax and the call
    delegates to `fastmax_causal_pallas` for bitwise parity. With
    `return_state=True` additionally returns the final MOMENT carry
    (m0, m1, m2, g0, g1, g2) in the fastmax layout — the band holds no
    carry (it is recomputed from k/v wherever needed), so the state
    shape is identical to fastmax's. Schedule knobs (blk/bm/grid) as in
    `fastmax_causal_pallas`."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    if hq % hkv:
        raise ValueError(f"Hq={hq} % Hkv={hkv} != 0")
    out_dtype = out_dtype or q.dtype

    cs = min(chunk_size, max(8, n))
    w_eff = max(0, min(window, cs))
    if w_eff == 0:
        from repro.kernels.fastmax_causal import fastmax_causal_pallas
        return fastmax_causal_pallas(
            q, k, v, kv_mask, p=p, chunk_size=chunk_size,
            denom_eps=denom_eps, interpret=interpret, out_dtype=out_dtype,
            return_state=return_state, blk=blk, bm=bm, grid=grid)
    nc = -(-n // cs)
    pad = nc * cs - n
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, d).reshape(b * hkv, g, nc * cs, d)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b * hkv, nc * cs, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b * hkv, nc * cs, dv)
    acc = jnp.promote_types(q.dtype, jnp.float32)
    if kv_mask is None:
        w = jnp.ones((b, hkv, n), acc)
    else:
        w = jnp.broadcast_to(kv_mask.astype(acc), (b, hkv, n))
    w = jnp.pad(w, ((0, 0), (0, 0), (0, pad))).reshape(b * hkv, nc * cs)

    if bm is None:
        bm = pick_bm(d)
    if d % bm:
        raise ValueError(f"bm={bm} must divide D={d}")
    if blk is None:
        blk = pick_blk(d, dv, FWD_BLK_BUDGET)
    if dv % blk:
        raise ValueError(f"blk={blk} must divide Dv={dv}")
    if grid is None:
        grid = "parallel"
    if grid not in ("parallel", "arbitrary"):
        raise ValueError(f"grid={grid!r}; expected 'parallel'|'arbitrary'")
    par = "parallel" if grid == "parallel" else "arbitrary"
    nb = dv // blk
    kernel = functools.partial(_hybrid_kernel, p=p, bm=bm, w_eff=w_eff,
                               denom_eps=denom_eps, acc=acc,
                               emit_state=return_state)
    bh = b * hkv
    m2_rows = d * d if p >= 2 else 1
    sm = lambda h, b_, c: (h, 0, 0)       # noqa: E731 g-carry state blocks
    vb = lambda h, b_, c: (h, 0, b_)      # noqa: E731 Dv-blocked m-state
    # previous-chunk blocks: index map pins chunk c-1 (clamped at 0; the
    # kernel nulls the c == 0 contribution via the validity gate)
    pc = lambda h, b_, c: (h, jnp.maximum(c - 1, 0), 0)   # noqa: E731
    pv = lambda h, b_, c: (h, jnp.maximum(c - 1, 0), b_)  # noqa: E731
    pw = lambda h, b_, c: (h, jnp.maximum(c - 1, 0))      # noqa: E731
    in_specs = [
        pl.BlockSpec((1, g, cs, d), lambda h, b_, c: (h, 0, c, 0)),
        pl.BlockSpec((1, cs, d), lambda h, b_, c: (h, c, 0)),
        pl.BlockSpec((1, cs, blk), lambda h, b_, c: (h, c, b_)),
        pl.BlockSpec((1, cs), lambda h, b_, c: (h, c)),
        pl.BlockSpec((1, cs, d), pc),
        pl.BlockSpec((1, cs, blk), pv),
        pl.BlockSpec((1, cs), pw),
    ]
    operands = [qp, kp, vp, w, kp, vp, w]
    out_specs = [pl.BlockSpec((1, g, cs, blk), lambda h, b_, c: (h, 0, c, b_))]
    out_shape = [jax.ShapeDtypeStruct((bh, g, nc * cs, dv), out_dtype)]
    if return_state:
        out_specs += [
            pl.BlockSpec((1, 1, blk), vb),
            pl.BlockSpec((1, d, blk), vb),
            pl.BlockSpec((1, m2_rows, blk), vb),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((bh, 1, dv), acc),
            jax.ShapeDtypeStruct((bh, d, dv), acc),
            jax.ShapeDtypeStruct((bh, m2_rows, dv), acc),
            jax.ShapeDtypeStruct((bh, 1, 1), acc),
            jax.ShapeDtypeStruct((bh, 1, d), acc),
            jax.ShapeDtypeStruct((bh, d, d), acc),
        ]
    outs = pl.pallas_call(
        kernel,
        grid=(bh, nb, nc),
        in_specs=in_specs,
        out_specs=out_specs if return_state else out_specs[0],
        out_shape=out_shape if return_state else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((1, blk), acc),
            pltpu.VMEM((d, blk), acc),
            pltpu.VMEM((d * d if p >= 2 else 1, blk), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
        ],
        # nb sequential when emitting state, as in fastmax_causal (the
        # g-state output block is shared across Dv-block programs)
        compiler_params=tpu_compiler_params(
            (par, "arbitrary" if return_state else par, "arbitrary")),
        interpret=interpret,
        name=f"hybrid_causal_p{p}_w{w_eff}",
    )(*operands)
    if not return_state:
        outs = [outs]
    out = outs[0].reshape(b, hkv, g, nc * cs, dv)[:, :, :, :n]
    out = out.reshape(b, hq, n, dv)
    if not return_state:
        return out
    m0, m1, m2, g0, g1, g2 = outs[1:]
    state = (
        m0.reshape(b, hkv, dv),
        m1.reshape(b, hkv, d, dv),
        (m2.reshape(b, hkv, d, d, dv) if p >= 2
         else jnp.zeros((b, hkv, d, d, dv), acc)),
        g0.reshape(b, hkv),
        g1.reshape(b, hkv, d),
        g2.reshape(b, hkv, d, d),
    )
    return out, state
