"""Pure-jnp oracle for the Pallas kernels.

Same GQA interface as the kernels (q:[B,Hq,N,D], k/v:[B,Hkv,M,*]); delegates
to the O(N^2) core reference. Kernels are validated against this in
interpret mode across shape/dtype sweeps (tests/test_kernels.py).

NOTE: kernels take PRE-NORMALIZED q̂, k̂ (normalization is done once by the
caller, outside the kernel), so this oracle runs with normalize=False.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ref import fastmax_attention_ref
from repro.core.fastmax import Moments, compute_moments, combine_with_queries

__all__ = ["fastmax_ref", "fastmax_decode_ref"]


def _bcast_kv(x: jnp.ndarray, hq: int) -> jnp.ndarray:
    b, hkv, m, d = x.shape
    g = hq // hkv
    return jnp.broadcast_to(
        x[:, :, None], (b, hkv, g, m, d)).reshape(b, hq, m, d)


def fastmax_ref(q, k, v, *, p=2, causal=False, denom_eps=1e-6):
    """Oracle with GQA broadcast; expects pre-normalized q̂/k̂."""
    hq = q.shape[1]
    kb, vb = _bcast_kv(k, hq), _bcast_kv(v, hq)
    return fastmax_attention_ref(
        q, kb, vb, p=p, causal=causal, normalize=False, denom_eps=denom_eps
    )


def fastmax_decode_ref(q, k, v, state, *, p=2, denom_eps=1e-6):
    """Oracle decode step on explicit moment-tuple state (pre-normalized)."""
    mom = Moments(*state)
    new = mom + compute_moments(k, v, p=p)
    b, hq, _, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(b, hkv, hq // hkv, d)
    num, den = combine_with_queries(qg, new, p=p)
    o = num / (den + denom_eps)[..., None]
    return o.reshape(b, hq, 1, -1).astype(q.dtype), tuple(new)
