"""Schedule autotuner for the fastmax/hybrid Pallas kernels.

Every schedule knob in the kernel stack used to be a static guess:
`tiling.pick_bm`/`pick_blk` are fixed VMEM-budget heuristics and
`chunk_size=128` was hard-coded at every call site. This module sweeps a
candidate set of schedules per (kernel, shape, dtype, platform) and
persists the winners, XLA-autotune-cache style:

  Schedule   the four knobs threaded through `repro.kernels.ops` into the
             kernels: `bm` (m-major row block), `blk` (Dv carry column
             block, causal fwd/bwd only), `chunk_size` (sequence chunk C),
             and `grid` (dimension semantics of the independent grid axes:
             "parallel" lets Mosaic split them across megacore,
             "arbitrary" forces a single-core sequential sweep).
  ShapeKey   (kernel, N, D, Dv, G, p, dtype, platform) — B and Hkv scale
             every candidate identically (they only widen the
             embarrassingly-parallel head axis), so they stay out of the
             key and one entry serves all batch sizes.

Two scoring backends:

  * measured — compile the kernel with the forced schedule and time it on
    the real device (median-of-k, warmup, block_until_ready). Only on TPU,
    and only outside an active trace (a lookup from inside someone's jit
    falls back to the cost model rather than running kernels mid-trace).
  * cost model — a deterministic analytic estimate (MXU-matmul flops, HBM
    bytes, per-grid-program overhead, VMEM-residency feasibility). This is
    the ONLY backend in interpret mode: CPU containers must never rank
    schedules by timing Python loops.

Env protocol (read per lookup, so tests can flip it):

  REPRO_AUTOTUNE=0 | unset   off — `lookup_schedule` returns None and the
                             kernels run their untuned `pick_*` defaults,
                             byte-identical to an autotune-free build.
  REPRO_AUTOTUNE=1           on — cache lookup; on a miss, tune (measure
                             on TPU, cost model elsewhere). The winner is
                             persisted back to REPRO_AUTOTUNE_CACHE when
                             that env var is explicitly set (the runtime
                             never mutates the committed in-repo cache).
  REPRO_AUTOTUNE=offline     cache lookup; on a miss, cost model only —
                             deterministic everywhere, never measures.
  REPRO_AUTOTUNE_CACHE=path  cache file (default: the committed
                             `src/repro/kernels/autotune_cache.json`).

Every lookup (including mode=off) records a provenance entry —
schedule + cache hit/miss/off + source — in a module-level log that the
benchmarks (`BENCH_attention.json` cells) and the dry-run (`attn_schedule`
next to `attn_routing`) snapshot, so perf regressions are attributable to
schedule changes.

CLI (the committed-cache workflow, `make autotune` / CI autotune job):

  python -m repro.kernels.autotune --write   # retune gate shapes, write
  python -m repro.kernels.autotune --check   # fail if committed is stale

The gate shapes are the dryrun-gate kernel cells (qwen2.5-32b train_4k /
decode_32k at TP=16 feature mode) plus the bench-json quick/full shapes.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import NamedTuple, Optional

from repro.kernels.tiling import (BWD_BLK_BUDGET, FWD_BLK_BUDGET,
                                  KERNEL_BM_BUDGET, divisors, pick_blk,
                                  pick_bm)

__all__ = ["Schedule", "ShapeKey", "KERNELS", "autotune_mode",
           "default_schedule", "candidate_schedules", "cost_model",
           "measure", "tune", "lookup_schedule", "load_cache", "save_cache",
           "key_str", "hardware_label", "clear_lookups", "snapshot_lookups",
           "gate_keys", "build_gate_entries", "DEFAULT_CACHE",
           "CACHE_VERSION"]

KERNELS = ("causal_fwd", "causal_bwd", "decode", "noncausal", "hybrid_fwd")
GRIDS = ("parallel", "arbitrary")

CACHE_VERSION = 1
DEFAULT_CACHE = os.path.join(os.path.dirname(__file__),
                             "autotune_cache.json")

# cost-model chip constants (v5e-class). Absolute seconds are irrelevant —
# only the deterministic RANKING of candidates matters.
MXU_FLOPS = 197e12          # peak matmul flop/s
HBM_BW = 819e9              # bytes/s
VMEM_BYTES = 16 * 2 ** 20   # per-core scratch + working-set ceiling
GRID_STEP_S = 2e-6          # fixed per-grid-program overhead
MEGACORE = 2                # "parallel" grid dims split across cores


class Schedule(NamedTuple):
    """One concrete kernel schedule (all knobs static / hashable)."""

    bm: int          # m-major row block (divides D)
    blk: int         # Dv carry column block (divides Dv; == Dv when unused)
    chunk_size: int  # sequence chunk C
    grid: str        # "parallel" | "arbitrary" (independent grid axes)


class ShapeKey(NamedTuple):
    kernel: str
    n: int
    d: int
    dv: int
    g: int
    p: int
    dtype: str
    platform: str


def key_str(key: ShapeKey) -> str:
    return (f"{key.kernel}|n={key.n},d={key.d},dv={key.dv},g={key.g},"
            f"p={key.p}|{key.dtype}|{key.platform}")


def autotune_mode() -> str:
    """'off' | 'on' | 'offline' from REPRO_AUTOTUNE (default off)."""
    env = os.environ.get("REPRO_AUTOTUNE", "0").strip().lower()
    if env in ("", "0", "off", "never"):
        return "off"
    if env in ("1", "on", "always"):
        return "on"
    if env == "offline":
        return "offline"
    raise ValueError(f"REPRO_AUTOTUNE={env!r}; expected 0, 1, or offline")


def _platform() -> str:
    import jax
    return jax.default_backend()


def hardware_label() -> str:
    """Bench-cell hardware label: compiled TPU vs interpret-mode host.

    The kernels compile only on TPU; everywhere else the Pallas bodies run
    in interpret mode, so off-TPU kernel timings are labeled
    '<platform>-interpret' and are never comparable across that boundary.
    """
    plat = _platform()
    return plat if plat == "tpu" else f"{plat}-interpret"


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------

def default_schedule(kernel: str, d: int, dv: int,
                     chunk_size: int) -> Schedule:
    """The untuned schedule — exactly what the kernels pick on their own."""
    if kernel in ("causal_fwd", "hybrid_fwd"):
        blk = pick_blk(d, dv, FWD_BLK_BUDGET)
    elif kernel == "causal_bwd":
        blk = pick_blk(d, dv, BWD_BLK_BUDGET)
    else:
        blk = dv   # decode / noncausal carry the full Dv width
    return Schedule(bm=pick_bm(d), blk=blk, chunk_size=chunk_size,
                    grid="parallel")


def candidate_schedules(kernel: str, key: ShapeKey,
                        chunk_size: int = 128) -> list:
    """The bounded sweep set for one kernel/shape (always contains the
    untuned default). Every emitted schedule is valid: bm | D, blk | Dv,
    and the scratch tuples fit the VMEM feasibility cap — the parity tests
    sweep exactly this list against the default schedule."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected {KERNELS}")
    d, dv, n = key.d, key.dv, key.n

    # bm: largest 3 divisors of D whose [bm*D, blk] tile stays MXU-sized
    bms = [bm for bm in divisors(d) if bm * d <= 4 * KERNEL_BM_BUDGET][-3:]

    if kernel in ("causal_fwd", "causal_bwd", "hybrid_fwd"):
        ntuples = 2 if kernel == "causal_bwd" else 1
        cap = VMEM_BYTES // 2    # leave headroom for the I/O tiles
        blks = [b for b in divisors(dv)
                if ntuples * d * d * b * 4 <= cap][-3:] or [1]
    else:
        blks = [dv]

    if kernel == "decode":
        chunks = [chunk_size]    # single-token step: no sequence chunking
    else:
        eff = {}
        for c in sorted({64, 128, 256, chunk_size}):
            eff.setdefault(min(c, max(8, n)), c)   # dedupe by effective C
        chunks = sorted(eff.values())[:3]

    out, seen = [], set()
    for sched in ([default_schedule(kernel, d, dv, chunk_size)]
                  + [Schedule(bm, blk, c, grid)
                     for bm in bms for blk in blks for c in chunks
                     for grid in GRIDS]):
        if sched not in seen:
            seen.add(sched)
            out.append(sched)
    return out


# ---------------------------------------------------------------------------
# deterministic analytic cost model
# ---------------------------------------------------------------------------

def _roof(flops: float, bytes_: float) -> float:
    return max(flops / MXU_FLOPS, bytes_ / HBM_BW)


def cost_model(key: ShapeKey, sched: Schedule) -> float:
    """Estimated seconds per (batch x kv-head) launch; inf = infeasible.

    Models the real tradeoffs of each kernel: the Dv-blocking replicates
    the Dv-independent work (QK^T, denominator, g-carry) nb times but is
    what keeps the [D², blk] scratch inside VMEM; small bm/chunk pay fixed
    per-grid-program overhead; "parallel" grids split across megacore.
    """
    n, d, dv, g, p = key.n, key.d, key.dv, key.g, key.p
    bm, blk, c, grid = sched
    inb = 2 if "bfloat16" in key.dtype or "float16" in key.dtype else 4
    d2 = d * d if p >= 2 else 1
    mega = MEGACORE if grid == "parallel" else 1

    if key.kernel in ("causal_fwd", "causal_bwd", "hybrid_fwd"):
        cs = min(c, max(8, n))
        nc = -(-n // cs)
        nb = dv // blk
        ntuples = 2 if key.kernel == "causal_bwd" else 1
        scratch = ntuples * (d2 * blk + d * blk + blk + d * d + d + 1) * 4
        io_tile = (g * cs * d + cs * d + cs * blk + g * cs * blk + cs) * inb
        if scratch + 2 * io_tile > VMEM_BYTES:
            return math.inf
        # per grid program (one chunk, one Dv block)
        flops = (2.0 * g * cs * cs * d            # QK^T   (Dv-independent)
                 + 2.0 * g * cs * cs * blk        # f(S) @ V
                 + 2.0 * g * cs * d * blk         # m1 contraction
                 + 2.0 * cs * d * blk)            # m1 update
        if p >= 2:
            flops += (2.0 * g * cs * d2 * blk     # m2 contraction
                      + 2.0 * cs * d2 * blk       # m2 update
                      + 2.0 * g * cs * d * d      # g2 denominator
                      + 2.0 * cs * d * d)         # g2 update
        if key.kernel == "hybrid_fwd":
            # band corrections: the previous-chunk score matmul and the
            # banded correction @ v (masking is elementwise; the block
            # shapes — and so the flops — don't depend on the window)
            flops += (2.0 * g * cs * cs * d       # prev-chunk QK^T
                      + 2.0 * g * cs * cs * blk)  # band corr @ V
            bytes_extra = (cs * d + cs * blk + cs) * inb  # prev k/v/mask
        else:
            bytes_extra = 0.0
        if key.kernel == "causal_bwd":
            # reversible reconstruct + recompute + 3 gradient matmuls +
            # carry-cotangent fold: ~2.5x the forward's per-chunk work
            flops *= 2.5
        bytes_ = io_tile + bytes_extra
        programs = nb * nc
        return (programs * _roof(flops, bytes_)
                + programs * GRID_STEP_S) / mega

    if key.kernel == "decode":
        nmb = d // bm if p >= 2 else 1
        tile = (bm * d * dv if p >= 2 else dv) * 4
        if 4 * tile > VMEM_BYTES:      # m2 block in + out, double-buffered
            return math.inf
        bytes_ = 2.0 * (d2 * dv + d * dv + dv + d * d + d + 1) * 4
        flops = 2.0 * (g + 1.0) * (d2 * dv + d * dv)
        return (_roof(flops, bytes_) + nmb * GRID_STEP_S) / mega

    # noncausal: phase A (moments) re-streams k/v once per m-block; phase B
    # (combine) re-reads the m2 tile once per query block
    cs = min(c, max(8, n))
    nc = -(-n // cs)
    nmb = d // bm if p >= 2 else 1
    tile = (bm * d * dv if p >= 2 else dv) * 4
    if 3 * tile + 2 * (cs * d + cs * dv) * inb > VMEM_BYTES:
        return math.inf
    a_flops = 2.0 * cs * (bm * d if p >= 2 else d) * dv
    a_bytes = (cs * d + cs * dv + cs) * inb
    b_flops = 2.0 * g * cs * (bm * d if p >= 2 else d) * dv
    b_bytes = tile + g * cs * (d + dv) * inb
    a = nmb * nc * (_roof(a_flops, a_bytes) + GRID_STEP_S)
    b = nc * nmb * (_roof(b_flops, b_bytes) + GRID_STEP_S)
    return (a + b) / mega


# ---------------------------------------------------------------------------
# real-hardware measurement
# ---------------------------------------------------------------------------

def measure(key: ShapeKey, sched: Schedule, *, iters: int = 5,
            warmup: int = 2, interpret: bool = False) -> float:
    """Median seconds per call of the compiled kernel under `sched`.

    Builds synthetic inputs at the key's shape (B=1, Hkv=1, Hq=G) and times
    the jitted wrapper with `block_until_ready`. Intended for TPU; passing
    interpret=True times the Python interpreter loop — tests only.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.fastmax_causal import fastmax_causal_pallas
    from repro.kernels.fastmax_causal_bwd import fastmax_causal_bwd_pallas
    from repro.kernels.fastmax_decode import fastmax_decode_pallas
    from repro.kernels.fastmax_noncausal import fastmax_noncausal_pallas

    n, d, dv, g, p = key.n, key.d, key.dv, key.g, key.p
    dtype = jnp.dtype(key.dtype)
    kk = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kk[0], (1, g, max(n, 1), d), dtype)
    k = jax.random.normal(kk[1], (1, 1, max(n, 1), d), dtype)
    v = jax.random.normal(kk[2], (1, 1, max(n, 1), dv), dtype)

    if key.kernel == "causal_fwd":
        fn = lambda: fastmax_causal_pallas(         # noqa: E731
            q, k, v, p=p, chunk_size=sched.chunk_size, interpret=interpret,
            bm=sched.bm, blk=sched.blk, grid=sched.grid)
    elif key.kernel == "hybrid_fwd":
        from repro.kernels.hybrid_causal import hybrid_causal_pallas
        fn = lambda: hybrid_causal_pallas(          # noqa: E731
            q, k, v, p=p, window=min(64, max(n, 1)),
            chunk_size=sched.chunk_size, interpret=interpret,
            bm=sched.bm, blk=sched.blk, grid=sched.grid)
    elif key.kernel == "causal_bwd":
        _, state = fastmax_causal_pallas(
            q, k, v, p=p, chunk_size=sched.chunk_size, interpret=interpret,
            return_state=True)
        do = jax.random.normal(kk[0], (1, g, max(n, 1), dv), dtype)
        fn = lambda: fastmax_causal_bwd_pallas(     # noqa: E731
            q, k, v, state, do, p=p, chunk_size=sched.chunk_size,
            interpret=interpret, bm=sched.bm, blk=sched.blk,
            grid=sched.grid)
    elif key.kernel == "decode":
        from repro.core.decode_state import init_fastmax_state
        state = tuple(init_fastmax_state(1, 1, d, dv, p=p))
        fn = lambda: fastmax_decode_pallas(         # noqa: E731
            q[:, :, :1], k[:, :, :1], v[:, :, :1], state, p=p,
            interpret=interpret, bm=sched.bm, grid=sched.grid)
    else:
        fn = lambda: fastmax_noncausal_pallas(      # noqa: E731
            q, k, v, p=p, chunk_size=sched.chunk_size, interpret=interpret,
            bm=sched.bm, grid=sched.grid)

    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _trace_clean() -> bool:
    """True when no jax trace is active (safe to execute kernels)."""
    import jax
    fn = getattr(jax.core, "trace_state_clean", None)
    try:
        return bool(fn()) if fn is not None else True
    except Exception:   # noqa: BLE001 — version drift; err on the safe side
        return False


# ---------------------------------------------------------------------------
# tuning + cache
# ---------------------------------------------------------------------------

def tune(key: ShapeKey, chunk_size: int = 128, *,
         allow_measure: bool = False):
    """Sweep the candidate set; returns (schedule, source, score).

    Measurement requires allow_measure AND a real TPU AND no active trace;
    everything else scores with the deterministic cost model (ties break on
    candidate order, so the winner is reproducible).
    """
    cands = candidate_schedules(key.kernel, key, chunk_size)
    measured = (allow_measure and key.platform == "tpu"
                and _platform() == "tpu" and _trace_clean())
    best, best_score = None, math.inf
    for sched in cands:
        if measured:
            if cost_model(key, sched) == math.inf:
                continue        # never launch a schedule the model rejects
            try:
                score = measure(key, sched)
            except Exception as e:   # noqa: BLE001 — bad candidate, skip
                print(f"autotune: measure failed for {key_str(key)} "
                      f"{sched}: {type(e).__name__}: {e}", file=sys.stderr)
                continue
        else:
            score = cost_model(key, sched)
        if score < best_score:
            best, best_score = sched, score
    if best is None:    # every candidate infeasible/failed: untuned default
        return (default_schedule(key.kernel, key.d, key.dv, chunk_size),
                "default", math.inf)
    return best, ("measured" if measured else "cost_model"), best_score


_FILE_CACHE: dict = {}   # path -> (mtime, entries)


def load_cache(path: str) -> dict:
    """Entries of the on-disk cache (mtime-memoized; {} when absent)."""
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    hit = _FILE_CACHE.get(path)
    if hit and hit[0] == mtime:
        return hit[1]
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"autotune: unreadable cache {path} ({e}) — ignoring",
              file=sys.stderr)
        return {}
    if raw.get("version") != CACHE_VERSION:
        print(f"autotune: cache {path} has version {raw.get('version')!r}, "
              f"expected {CACHE_VERSION} — ignoring", file=sys.stderr)
        return {}
    entries = raw.get("entries", {})
    _FILE_CACHE[path] = (mtime, entries)
    return entries


def save_cache(path: str, entries: dict) -> None:
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION,
                   "entries": {k: entries[k] for k in sorted(entries)}},
                  f, indent=2)
        f.write("\n")
    _FILE_CACHE.pop(path, None)


def _entry_schedule(entry: dict, key: ShapeKey) -> Optional[Schedule]:
    """Validate + decode a cache entry against the key's shape (a stale
    entry whose blocks no longer divide the dims is treated as a miss)."""
    try:
        s = Schedule(**{f: entry["schedule"][f] for f in Schedule._fields})
    except (KeyError, TypeError):
        return None
    if (key.d % s.bm or key.dv % s.blk or s.chunk_size < 1
            or s.grid not in GRIDS):
        return None
    return s


# provenance: one record per distinct lookup key, snapshot by the
# benchmarks and the dry-run (cleared per cell like registry._LOGGED)
_LOOKUPS: dict = {}
_MISS_MEMO: dict = {}


def clear_lookups() -> None:
    _LOOKUPS.clear()


def snapshot_lookups() -> list:
    return [_LOOKUPS[k] for k in sorted(_LOOKUPS)]


def _record(key: ShapeKey, sched: Schedule, cache: str, source: str):
    _LOOKUPS[key_str(key)] = {
        "kernel": key.kernel,
        "key": key_str(key),
        "schedule": dict(sched._asdict()),
        "cache": cache,      # "hit" | "miss" | "off"
        "source": source,    # "measured" | "cost_model" | "default"
    }


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE)


def lookup_schedule(kernel: str, *, n: int, d: int, dv: int, g: int,
                    p: int, dtype, chunk_size: int) -> Optional[Schedule]:
    """The runtime entry point, called by `repro.kernels.ops` per launch.

    Returns None when autotuning is off (the kernels then run their
    untuned `pick_*` defaults — byte-identical to an autotune-free build);
    otherwise the cached or freshly tuned Schedule. Every call records a
    provenance entry regardless of mode.
    """
    mode = autotune_mode()
    key = ShapeKey(kernel, int(n), int(d), int(dv), int(g), int(p),
                   str(jnp_dtype_name(dtype)), _platform())
    if mode == "off":
        _record(key, default_schedule(kernel, d, dv, chunk_size),
                cache="off", source="default")
        return None
    path = cache_path()
    ks = key_str(key)
    entry = load_cache(path).get(ks)
    if entry is not None:
        sched = _entry_schedule(entry, key)
        if sched is not None:
            _record(key, sched, cache="hit",
                    source=entry.get("source", "cost_model"))
            return sched
    memo_key = (mode, path, ks)
    if memo_key in _MISS_MEMO:
        sched, source = _MISS_MEMO[memo_key]
        _record(key, sched, cache="miss", source=source)
        return sched
    sched, source, score = tune(key, chunk_size,
                                allow_measure=(mode == "on"))
    _MISS_MEMO[memo_key] = (sched, source)
    _record(key, sched, cache="miss", source=source)
    if mode == "on" and "REPRO_AUTOTUNE_CACHE" in os.environ:
        # persist like XLA's autotune cache — but only to a path the user
        # explicitly owns; the committed in-repo default is CLI-managed
        entries = dict(load_cache(path))
        entries[ks] = {"schedule": dict(sched._asdict()), "source": source,
                       "score": None if math.isinf(score) else score}
        try:
            save_cache(path, entries)
        except OSError as e:
            print(f"autotune: could not persist to {path} ({e})",
                  file=sys.stderr)
    return sched


def jnp_dtype_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


# ---------------------------------------------------------------------------
# gate shapes + CLI (the committed-cache workflow)
# ---------------------------------------------------------------------------

def gate_keys(platform: str = "cpu") -> list:
    """(ShapeKey, chunk_size) for every kernel cell the dryrun-gate and the
    bench-json suite exercise — the shapes the committed cache must cover."""
    from repro.configs import SHAPES, get_config

    out = []
    # bench-json attention_phases shapes (quick / full), f32, p=2
    for n, d, dv, g in ((256, 16, 16, 2), (2048, 64, 64, 2)):
        out += [(ShapeKey("causal_fwd", n, d, dv, g, 2, "float32",
                          platform), 128),
                (ShapeKey("causal_bwd", n, d, dv, g, 2, "float32",
                          platform), 128),
                (ShapeKey("decode", 1, d, dv, g, 2, "float32",
                          platform), 128),
                (ShapeKey("noncausal", n, d, dv, g, 2, "float32",
                          platform), 128),
                (ShapeKey("hybrid_fwd", n, d, dv, g, 2, "float32",
                          platform), 128)]
    # dryrun-gate kernel cells: qwen2.5-32b at TP=16 routes feature mode
    # (hkv=8 does not divide 16; Dv does), so the per-device launches see
    # the LOCAL Dv shard; q/k stay replicated at full head_dim
    cfg = get_config("qwen2.5-32b")
    tp = 16
    d = cfg.head_dim
    dvl = cfg.head_dim // tp
    g = cfg.n_heads // cfg.n_kv_heads
    dt = "bfloat16" if cfg.activ_dtype == "bfloat16" else "float32"
    n_train = SHAPES["train_4k"].seq_len
    out += [(ShapeKey("causal_fwd", n_train, d, dvl, g, 2, dt, platform),
             128),
            (ShapeKey("causal_bwd", n_train, d, dvl, g, 2, dt, platform),
             128),
            (ShapeKey("decode", 1, d, dvl, g, 2, dt, platform), 128),
            # hybrid train_4k cell: the feature-mode forward launches see
            # the same local Dv shard; the backward is the jnp band scan
            # (no kernel), so only hybrid_fwd needs an entry
            (ShapeKey("hybrid_fwd", n_train, d, dvl, g, 2, dt, platform),
             128)]
    return out


def build_gate_entries(platform: str = "cpu") -> dict:
    """Cost-model winners for every gate shape (deterministic on any host)."""
    entries = {}
    for key, chunk in gate_keys(platform):
        sched, source, score = tune(key, chunk, allow_measure=False)
        entries[key_str(key)] = {
            "schedule": dict(sched._asdict()),
            "source": source,
            "score": None if math.isinf(score) else score,
        }
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fastmax kernel schedule autotuner (committed-cache "
                    "workflow; runtime tuning is env-driven, see module "
                    "docstring)")
    ap.add_argument("--cache", default=DEFAULT_CACHE,
                    help="cache file (default: the committed in-repo one)")
    ap.add_argument("--platform", default="cpu",
                    help="platform tag for the generated entries")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--write", action="store_true",
                   help="retune the gate shapes (cost model) and write "
                        "them into the cache, preserving other entries")
    g.add_argument("--check", action="store_true",
                   help="fail if the committed cache is stale vs a fresh "
                        "cost-model sweep (schema or winner drift)")
    args = ap.parse_args()

    fresh = build_gate_entries(args.platform)
    if args.write:
        entries = dict(load_cache(args.cache))
        entries.update(fresh)
        save_cache(args.cache, entries)
        print(f"autotune: wrote {len(fresh)} gate entries "
              f"({len(entries)} total) to {args.cache}")
        return

    drift = []
    try:
        with open(args.cache) as f:
            raw = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SystemExit(f"autotune --check: cannot read {args.cache}: {e}")
    if raw.get("version") != CACHE_VERSION:
        drift.append(f"schema version {raw.get('version')!r} != "
                     f"{CACHE_VERSION}")
    committed = raw.get("entries", {})
    for ks, entry in fresh.items():
        have = committed.get(ks)
        if have is None:
            drift.append(f"missing entry: {ks}")
        elif have.get("schedule") != entry["schedule"]:
            drift.append(f"winner drift: {ks}: committed "
                         f"{have.get('schedule')} != fresh "
                         f"{entry['schedule']}")
    if drift:
        for line in drift:
            print(f"autotune --check: STALE — {line}")
        raise SystemExit(
            f"autotune --check: {len(drift)} stale entr"
            f"{'y' if len(drift) == 1 else 'ies'} — regenerate with "
            f"`make autotune` and commit the cache")
    print(f"autotune --check: OK ({len(fresh)} gate entries up to date "
          f"in {args.cache})")


if __name__ == "__main__":
    main()
