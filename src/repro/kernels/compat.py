"""JAX version compatibility for the Pallas TPU kernels.

The TPU compiler-params class was renamed across JAX releases:
`pltpu.TPUCompilerParams` (<= 0.4.x / early 0.5.x) became
`pltpu.CompilerParams` (newer releases). Resolve whichever exists once so
every kernel builds against any installed JAX.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["tpu_compiler_params"]


def tpu_compiler_params(dimension_semantics, **kwargs):
    """Build TPU compiler params portably across JAX versions."""
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=tuple(dimension_semantics), **kwargs)
