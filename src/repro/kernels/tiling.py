"""Shared tiling policy for the fastmax m-blocked degree-2 contractions.

Two independent blockings of the degree-2 moment `m2 [D·D, Dv]` (m-major):

* `pick_bm` — the ROW (first-moment-index) streaming block. Both the jnp
  chunked scan (`repro.core.fastmax`) and the Pallas kernels slice the
  working tile to [bm*D, Dv] so the per-step intermediates are [*, bm*D].
  bm is the largest divisor of D whose flattened row count bm*D stays
  under a budget: ~512 rows for VMEM-resident kernel tiles (MXU-friendly
  inner matmuls), ~2048 for the XLA scan path (bounds the [..., N, bm*D]
  intermediate that the naive einsum would blow up to [..., N, D, Dv]).

* `pick_blk` — the COLUMN (value-feature, Dv) carry block. The causal
  forward/backward kernels hold the RUNNING moment carry in VMEM scratch;
  at D = Dv = 128 a full degree-2 tuple is D²·Dv·4 = 8 MB, and the fused
  backward needs TWO (carry + carry-cotangent) — past the ~16 MB/core
  VMEM wall. Both kernels therefore tile the Dv axis of the carry into
  `nb = Dv/blk` independent column blocks (a grid axis): per-block scratch
  is D²·blk·4 bytes, the chunk forward is recomputed once per block from
  the reversible carry, and every emitted quantity either slices (o, dv,
  the m-moments) or sums (dq, dk — the contractions over Dv are linear in
  the per-block cotangents) across blocks. blk is the largest divisor of
  Dv with D²·blk at most the budget: 2M f32 words (8 MB) for the forward's
  single tuple, 1M (4 MB each, 8 MB for the pair) for the backward — so
  128×128 heads train with nb_fwd = 1, nb_bwd = 2, and small heads keep
  nb = 1 (the unblocked schedule, bit-identical to before).

Both pickers are the UNTUNED defaults: the schedule autotuner
(`repro.kernels.autotune`) sweeps bm/blk (among other knobs) per shape and
overrides them when enabled; it also calls these per candidate inside the
sweep loop, so they enumerate divisors in O(sqrt(d)) instead of scanning
every integer up to d.
"""
from __future__ import annotations

import functools

__all__ = ["pick_bm", "pick_blk", "divisors", "KERNEL_BM_BUDGET",
           "SCAN_BM_BUDGET", "FWD_BLK_BUDGET", "BWD_BLK_BUDGET"]

KERNEL_BM_BUDGET = 512   # Pallas VMEM tiles
SCAN_BM_BUDGET = 2048    # jnp chunked-scan intermediates

FWD_BLK_BUDGET = 2 << 20   # f32 words per degree-2 carry tuple (1 tuple)
BWD_BLK_BUDGET = 1 << 20   # f32 words per tuple (carry + cotangent pair)


@functools.lru_cache(maxsize=None)
def divisors(n: int) -> tuple:
    """All divisors of `n`, ascending (n >= 1)."""
    if not isinstance(n, int) or n < 1:
        raise ValueError(f"divisors() needs a positive int, got {n!r}")
    small, large = [], []
    i = 1
    while i * i <= n:
        if n % i == 0:
            small.append(i)
            if i != n // i:
                large.append(n // i)
        i += 1
    return tuple(small + large[::-1])


def _check_budget(budget) -> int:
    if not isinstance(budget, int) or budget < 1:
        raise ValueError(f"budget must be a positive int, got {budget!r}")
    return budget


@functools.lru_cache(maxsize=None)
def pick_bm(d: int, budget: int = KERNEL_BM_BUDGET) -> int:
    """Largest divisor of `d` with bm*d <= budget (always >= 1)."""
    _check_budget(budget)
    best = 1
    for bm in divisors(d):
        if bm * d <= budget:
            best = bm   # divisors ascend, so the last feasible is largest
    return best


@functools.lru_cache(maxsize=None)
def pick_blk(d: int, dv: int, budget: int = FWD_BLK_BUDGET) -> int:
    """Largest divisor of `dv` with d*d*blk <= budget (always >= 1).

    The Dv carry-block of the causal kernels: one degree-2 scratch tuple
    is d*d*blk f32 words per grid program. blk == dv means nb == 1 — the
    unblocked schedule.
    """
    _check_budget(budget)
    if not isinstance(d, int) or d < 1:
        raise ValueError(f"d must be a positive int, got {d!r}")
    best = 1
    for blk in divisors(dv):
        if d * d * blk <= budget:
            best = blk
    return best
