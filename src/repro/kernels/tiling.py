"""Shared tiling policy for the fastmax m-blocked degree-2 contractions.

Both the jnp chunked scan (`repro.core.fastmax`) and the Pallas kernels
block the degree-2 moment over its first index so the working tile is
[bm*D, Dv] and the per-step intermediates are [*, bm*D]. The block size is
the largest divisor of D whose flattened row count bm*D stays under a
budget: ~512 rows for VMEM-resident kernel tiles (MXU-friendly inner
matmuls), ~2048 for the XLA scan path (bounds the [..., N, bm*D]
intermediate that the naive einsum would blow up to [..., N, D, Dv]).
"""
from __future__ import annotations

import functools

__all__ = ["pick_bm", "KERNEL_BM_BUDGET", "SCAN_BM_BUDGET"]

KERNEL_BM_BUDGET = 512   # Pallas VMEM tiles
SCAN_BM_BUDGET = 2048    # jnp chunked-scan intermediates


@functools.lru_cache(maxsize=None)
def pick_bm(d: int, budget: int = KERNEL_BM_BUDGET) -> int:
    """Largest divisor of `d` with bm*d <= budget (always >= 1)."""
    best = 1
    for bm in range(1, d + 1):
        if d % bm == 0 and bm * d <= budget:
            best = bm
    return best
