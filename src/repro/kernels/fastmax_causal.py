"""Pallas TPU kernel: causal Fastmax attention via chunked prefix scan.

TPU-native redesign of the paper's masked Fastmax (DESIGN.md §2). The paper's
GPU code carries *per-row* prefix moments (O(N D^{p+1}) memory → the D× causal
wall-clock penalty they report in §3.1). Here the sequence is processed in
chunks of C tokens along a sequential grid axis; the running moments live in
VMEM scratch (O(D^{p+1}) bytes total), and every heavy op is an MXU matmul:

  intra-chunk:  S = Q K^T  (C×C),  f(S) masked, f(S)·V
  inter-chunk:  φ₂(Q) contracted against the moment carry, blocked over the
                first moment index so each step is a
                [G·C, bm·D] @ [bm·D, blk] matmul (bm chosen so bm·D ≈ 256-512)

Layout notes (TPU):
  * degree-2 moment scratch is [D·D, blk] (m-major) so both the update
    (T^T @ V) and the query contraction slice contiguous row blocks — no
    reshapes of scratch, only a [C, bm, D] → [C, bm·D] collapse of the
    last two dims of a freshly built tile.
  * the VALUE-FEATURE axis of the carry (and of v / o / the emitted
    m-moments) is tiled into nb = Dv/blk independent column blocks
    (`pick_blk`): per-block scratch is D²·blk·4 bytes, so D = Dv = 128
    heads fit VMEM (blk = Dv ⇒ nb = 1 reproduces the unblocked schedule
    exactly). Each block redundantly recomputes the Dv-independent parts
    (QK^T, the denominator, the g-carry) and emits ITS slice of o and the
    m-moments — outputs slice cleanly because o = num/(den+eps) splits
    along Dv.
  * grid = (B·Hkv, nb, N/C): head and Dv-block axes "parallel"
    (independent), chunk axis "arbitrary" (sequential — the scan carry).
  * GQA: Q arrives [B·Hkv, G, N, D]; the G query heads of a group are
    flattened into matmul rows so moments are computed ONCE per kv head
    (the paper's reference code recomputes them per q head).
  * fp32 accumulation regardless of input dtype (f64 in interpret tests).

Validated against `repro.kernels.ref.fastmax_ref` in interpret mode
(tests/test_kernels.py) across shapes, dtypes, p∈{1,2}, and GQA group sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.tiling import FWD_BLK_BUDGET, pick_blk, pick_bm

__all__ = ["fastmax_causal_pallas"]


def _poly(s, p):
    out = 1.0 + s
    if p >= 2:
        out = out + 0.5 * s * s
    return out


def _causal_kernel(
    q_ref,   # [1, G, C, D]
    k_ref,   # [1, C, D]
    v_ref,   # [1, C, Dv]
    w_ref,   # [1, C]       validity mask (1=real token, 0=padding)
    *refs,   # [init-state inputs (has_init)] + o_ref +
    #          [state outputs (emit_state)] + 6 moment scratch buffers
    p: int,
    bm: int,
    denom_eps: float,
    acc,
    emit_state: bool,
    has_init: bool,
):
    if has_init:
        # initial carry: tokens already folded before this call (context-
        # parallel shards / resumable prefill) — same layout as the emitted
        # state, read once at the first chunk
        (i0, i1, i2, j0, j1, j2) = refs[:6]
        refs = refs[6:]
    o_ref = refs[0]
    refs = refs[1:]
    if emit_state:
        # final-carry outputs, m-major m2 — the decode kernel's native layout
        (m0o, m1o, m2o, g0o, g1o, g2o) = refs[:6]
        refs = refs[6:]
    m0_s, m1_s, m2_s, g0_s, g1_s, g2_s = refs
    c = pl.program_id(2)
    nc = pl.num_programs(2)
    g, cs, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = v_ref.shape[2]

    f32 = acc
    @pl.when(c == 0)
    def _init():
        if has_init:
            m0_s[...] = i0[0]
            m1_s[...] = i1[0]
            g0_s[...] = j0[0]
            g1_s[...] = j1[0]
            if p >= 2:
                m2_s[...] = i2[0]
                g2_s[...] = j2[0]
        else:
            m0_s[...] = jnp.zeros_like(m0_s)
            m1_s[...] = jnp.zeros_like(m1_s)
            g0_s[...] = jnp.zeros_like(g0_s)
            g1_s[...] = jnp.zeros_like(g1_s)
            if p >= 2:
                m2_s[...] = jnp.zeros_like(m2_s)
                g2_s[...] = jnp.zeros_like(g2_s)

    q = q_ref[0].astype(f32).reshape(g * cs, d)   # [GC, D]
    k = k_ref[0].astype(f32)                      # [C, D]
    v = v_ref[0].astype(f32)                      # [C, Dv]
    w = w_ref[0].astype(f32)                      # [C]

    # ---- inter-chunk: contract carry (strictly-previous chunks) with q ----
    num = jnp.broadcast_to(m0_s[...], (g * cs, dv)) + jnp.dot(
        q, m1_s[...], preferred_element_type=f32
    )
    den = g0_s[0, 0] + jnp.dot(q, g1_s[0], preferred_element_type=f32)
    if p >= 2:
        den = den + 0.5 * jnp.sum(
            jnp.dot(q, g2_s[...], preferred_element_type=f32) * q,
            axis=-1,
        )

        def mb_step(i, acc):
            qm = jax.lax.dynamic_slice_in_dim(q, i * bm, bm, 1)  # [GC, bm]
            y = (qm[:, :, None] * q[:, None, :]).reshape(g * cs, bm * d)
            z = m2_s[pl.dslice(i * bm * d, bm * d), :]      # [bm*D, Dv]
            return acc + jnp.dot(y, z, preferred_element_type=f32)

        num = num + 0.5 * jax.lax.fori_loop(
            0, d // bm, mb_step, jnp.zeros((g * cs, dv), f32)
        )

    # ---- intra-chunk: exact causal block through f(QK^T) ----
    s = jnp.dot(q, k.T, preferred_element_type=f32)  # [GC, C]
    fs = _poly(s, p)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (g * cs, cs), 0) % cs
    kpos = jax.lax.broadcasted_iota(jnp.int32, (g * cs, cs), 1)
    fs = jnp.where(qpos >= kpos, fs, 0.0) * w[None, :]
    num = num + jnp.dot(fs, v, preferred_element_type=f32)
    den = den + jnp.sum(fs, axis=-1)

    o = num / (den + denom_eps)[:, None]
    o_ref[0] = o.reshape(g, cs, dv).astype(o_ref.dtype)

    # ---- fold this chunk into the carry ----
    kw = k * w[:, None]
    vw = v * w[:, None]
    m0_s[...] += jnp.sum(vw, axis=0, keepdims=True)
    m1_s[...] += jnp.dot(kw.T, v, preferred_element_type=f32)
    g0_s[...] += jnp.sum(w).reshape(1, 1)
    g1_s[...] += jnp.sum(kw, axis=0, keepdims=True)
    if p >= 2:
        g2_s[...] += jnp.dot(kw.T, k, preferred_element_type=f32)

        def mb_up(i, _):
            km = jax.lax.dynamic_slice_in_dim(k, i * bm, bm, 1)  # [C, bm]
            t = (km[:, :, None] * k[:, None, :]).reshape(cs, bm * d)
            m2_s[pl.dslice(i * bm * d, bm * d), :] += jnp.dot(
                t.T, vw, preferred_element_type=f32
            )
            return 0

        jax.lax.fori_loop(0, d // bm, mb_up, 0)

    if emit_state:
        @pl.when(c == nc - 1)
        def _emit_state():
            m0o[0] = m0_s[...]
            m1o[0] = m1_s[...]
            g0o[0] = g0_s[...]
            g1o[0] = g1_s[...]
            if p >= 2:
                m2o[0] = m2_s[...]
                g2o[0] = g2_s[...]
            else:
                m2o[0] = jnp.zeros_like(m2o[0])
                g2o[0] = jnp.zeros_like(g2o[0])


@functools.partial(
    jax.jit,
    static_argnames=("p", "chunk_size", "denom_eps", "interpret", "out_dtype",
                     "return_state", "blk", "bm", "grid"),
)
def fastmax_causal_pallas(
    q: jnp.ndarray,  # [B, Hq, N, D]  (pre-normalized q̂)
    k: jnp.ndarray,  # [B, Hkv, N, D] (pre-normalized k̂)
    v: jnp.ndarray,  # [B, Hkv, N, Dv]
    kv_mask: jnp.ndarray | None = None,  # [B, Hkv|1, N] validity (1=real)
    *,
    p: int = 2,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool = False,
    out_dtype=None,
    return_state: bool = False,
    init_state=None,
    blk: int | None = None,
    bm: int | None = None,
    grid: str | None = None,
):
    """Causal fastmax. With `return_state=True` additionally returns the
    final moment carry as a tuple (m0, m1, m2, g0, g1, g2) with shapes
    ([B,Hkv,Dv], [B,Hkv,D,Dv], [B,Hkv,D,D,Dv], [B,Hkv], [B,Hkv,D],
    [B,Hkv,D,D]) in the accumulator dtype — emitted by the kernel itself
    (no second pass over k/v), ready for streaming decode.

    `init_state` seeds the scan carry with a moment tuple in that same
    layout (tokens already folded upstream: the earlier context-parallel
    shards of the sequence, or the already-prefilled prompt prefix). The
    scan then computes the EXACT causal output as if those tokens preceded
    this call's k/v — the associativity of the moment fold.

    `blk` is the Dv carry-block width (must divide Dv); None picks the
    largest divisor whose degree-2 scratch tuple fits `FWD_BLK_BUDGET`
    (nb = Dv/blk = 1 below 128×128 heads — the unblocked schedule).
    `bm` is the m-major row block (must divide D; None → `pick_bm`).
    `grid` selects the dimension semantics of the INDEPENDENT grid axes:
    "parallel" (None; megacore may split them) or "arbitrary" (sequential
    single-core sweep) — the autotuner's schedule knobs."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    if hq % hkv:
        raise ValueError(f"Hq={hq} % Hkv={hkv} != 0")
    out_dtype = out_dtype or q.dtype

    cs = min(chunk_size, max(8, n))
    nc = -(-n // cs)
    pad = nc * cs - n
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b, hkv, g, nc * cs, d).reshape(b * hkv, g, nc * cs, d)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b * hkv, nc * cs, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(
        b * hkv, nc * cs, dv)
    acc = jnp.promote_types(q.dtype, jnp.float32)
    if kv_mask is None:
        w = jnp.ones((b, hkv, n), acc)
    else:
        w = jnp.broadcast_to(kv_mask.astype(acc), (b, hkv, n))
    w = jnp.pad(w, ((0, 0), (0, 0), (0, pad))).reshape(b * hkv, nc * cs)

    if bm is None:
        bm = pick_bm(d)
    if d % bm:
        raise ValueError(f"bm={bm} must divide D={d}")
    if blk is None:
        blk = pick_blk(d, dv, FWD_BLK_BUDGET)
    if dv % blk:
        raise ValueError(f"blk={blk} must divide Dv={dv}")
    if grid is None:
        grid = "parallel"
    if grid not in ("parallel", "arbitrary"):
        raise ValueError(f"grid={grid!r}; expected 'parallel'|'arbitrary'")
    par = "parallel" if grid == "parallel" else "arbitrary"
    nb = dv // blk
    has_init = init_state is not None
    kernel = functools.partial(_causal_kernel, p=p, bm=bm, denom_eps=denom_eps,
                               acc=acc, emit_state=return_state,
                               has_init=has_init)
    bh = b * hkv
    m2_rows = d * d if p >= 2 else 1
    sm = lambda h, b_, c: (h, 0, 0)       # noqa: E731 g-carry state blocks
    vb = lambda h, b_, c: (h, 0, b_)      # noqa: E731 Dv-blocked m-state
    in_specs = [
        pl.BlockSpec((1, g, cs, d), lambda h, b_, c: (h, 0, c, 0)),
        pl.BlockSpec((1, cs, d), lambda h, b_, c: (h, c, 0)),
        pl.BlockSpec((1, cs, blk), lambda h, b_, c: (h, c, b_)),
        pl.BlockSpec((1, cs), lambda h, b_, c: (h, c)),
    ]
    operands = [qp, kp, vp, w]
    if has_init:
        i0, i1, i2, j0, j1, j2 = init_state
        operands += [
            i0.astype(acc).reshape(bh, 1, dv),
            i1.astype(acc).reshape(bh, d, dv),
            (i2.astype(acc).reshape(bh, d * d, dv) if p >= 2
             else jnp.zeros((bh, 1, dv), acc)),
            j0.astype(acc).reshape(bh, 1, 1),
            j1.astype(acc).reshape(bh, 1, d),
            j2.astype(acc).reshape(bh, d, d),
        ]
        in_specs += [
            pl.BlockSpec((1, 1, blk), vb),
            pl.BlockSpec((1, d, blk), vb),
            pl.BlockSpec((1, m2_rows, blk), vb),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ]
    out_specs = [pl.BlockSpec((1, g, cs, blk), lambda h, b_, c: (h, 0, c, b_))]
    out_shape = [jax.ShapeDtypeStruct((bh, g, nc * cs, dv), out_dtype)]
    if return_state:
        out_specs += [
            pl.BlockSpec((1, 1, blk), vb),
            pl.BlockSpec((1, d, blk), vb),
            pl.BlockSpec((1, m2_rows, blk), vb),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((bh, 1, dv), acc),
            jax.ShapeDtypeStruct((bh, d, dv), acc),
            jax.ShapeDtypeStruct((bh, m2_rows, dv), acc),
            jax.ShapeDtypeStruct((bh, 1, 1), acc),
            jax.ShapeDtypeStruct((bh, 1, d), acc),
            jax.ShapeDtypeStruct((bh, d, d), acc),
        ]
    outs = pl.pallas_call(
        kernel,
        grid=(bh, nb, nc),
        in_specs=in_specs,
        out_specs=out_specs if return_state else out_specs[0],
        out_shape=out_shape if return_state else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((1, blk), acc),
            pltpu.VMEM((d, blk), acc),
            pltpu.VMEM((d * d if p >= 2 else 1, blk), acc),
            pltpu.VMEM((1, 1), acc),
            pltpu.VMEM((1, d), acc),
            pltpu.VMEM((d, d), acc),
        ],
        # nb must be sequential when emitting state: every Dv-block program
        # writes the SAME g-state output block (identical values), and
        # aliasing an output window across a "parallel" grid dim is
        # undefined on megacore (two cores would DMA it concurrently).
        # Without state outputs every block writes disjoint o slices, so
        # nb follows the schedule's `grid` knob.
        compiler_params=tpu_compiler_params(
            (par, "arbitrary" if return_state else par, "arbitrary")),
        interpret=interpret,
        name=f"fastmax_causal_p{p}",
    )(*operands)
    if not return_state:
        outs = [outs]
    out = outs[0].reshape(b, hkv, g, nc * cs, dv)[:, :, :, :n]
    out = out.reshape(b, hq, n, dv)
    if not return_state:
        return out
    m0, m1, m2, g0, g1, g2 = outs[1:]
    state = (
        m0.reshape(b, hkv, dv),
        m1.reshape(b, hkv, d, dv),
        (m2.reshape(b, hkv, d, d, dv) if p >= 2
         else jnp.zeros((b, hkv, d, d, dv), acc)),
        g0.reshape(b, hkv),
        g1.reshape(b, hkv, d),
        g2.reshape(b, hkv, d, d),
    )
    return out, state
