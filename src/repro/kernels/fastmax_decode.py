"""Pallas TPU kernel: single-token Fastmax decode step.

The serving hot loop. State = moment tuple (O(1) in context length,
DESIGN.md §2). Per step and kv-head this kernel:

  1. folds the new (k̂, v) into the moments (rank-1 update of m2, streamed
     in m-blocks so the [D·D, Dv] tensor is read+written exactly once),
  2. contracts φ(q̂) of the G grouped query heads against the updated
     moments (the [G, bm·D] @ [bm·D, Dv] matmuls ride the same m2 stream).

Decode is memory-bound on streaming m2 (D²·Dv·4 bytes ≈ 8 MB/head for
D=Dv=128); fusing update+combine halves HBM traffic vs two separate ops and
is why this kernel exists. HBM state buffers are reused in place via
input_output_aliases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.tiling import pick_bm

__all__ = ["fastmax_decode_pallas"]


def _decode_kernel(q_ref, k_ref, v_ref,
                   m0_ref, m1_ref, m2_ref, g0_ref, g1_ref, g2_ref,
                   o_ref, m0o, m1o, m2o, g0o, g1o, g2o,
                   acc_s, den_s, *, p, bm, nmb, denom_eps, acc):
    mb = pl.program_id(1)
    g, d = q_ref.shape[1], q_ref.shape[2]
    dv = v_ref.shape[2]
    q = q_ref[0].astype(acc)       # [G, D]
    k = k_ref[0, 0].astype(acc)    # [D]
    v = v_ref[0, 0].astype(acc)    # [Dv]

    @pl.when(mb == 0)
    def _small():
        m0 = m0_ref[0] + v[None, :]
        m1 = m1_ref[0] + k[:, None] * v[None, :]
        g0 = g0_ref[0] + 1.0
        g1 = g1_ref[0] + k[None, :]
        m0o[0], m1o[0], g0o[0], g1o[0] = m0, m1, g0, g1
        num = jnp.broadcast_to(m0, (g, dv)) + jnp.dot(
            q, m1, preferred_element_type=acc)
        den = g0[0, 0] + jnp.dot(q, g1[0], preferred_element_type=acc)
        if p >= 2:
            g2 = g2_ref[0] + k[:, None] * k[None, :]
            g2o[0] = g2
            den = den + 0.5 * jnp.sum(
                jnp.dot(q, g2, preferred_element_type=acc) * q, axis=-1)
        else:
            g2o[0] = g2_ref[0]
            m2o[0] = m2_ref[0]
        acc_s[...] = num
        den_s[...] = den[:, None]

    if p >= 2:
        km = jax.lax.dynamic_slice_in_dim(k, mb * bm, bm, 0)  # [bm]
        t = (km[:, None] * k[None, :]).reshape(bm * d)       # [bm*D]
        m2 = m2_ref[0] + t[:, None] * v[None, :]             # [bm*D, Dv]
        m2o[0] = m2
        qm = jax.lax.dynamic_slice_in_dim(q, mb * bm, bm, 1)
        y = (qm[:, :, None] * q[:, None, :]).reshape(g, bm * d)
        acc_s[...] += 0.5 * jnp.dot(y, m2, preferred_element_type=acc)

    @pl.when(mb == nmb - 1)
    def _emit():
        o_ref[0] = (acc_s[...] / (den_s[...] + denom_eps)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("p", "denom_eps", "interpret", "out_dtype",
                              "bm", "grid")
)
def fastmax_decode_pallas(
    q: jnp.ndarray,   # [B, Hq, 1, D]   pre-normalized q̂ of the new token
    k: jnp.ndarray,   # [B, Hkv, 1, D]  pre-normalized k̂
    v: jnp.ndarray,   # [B, Hkv, 1, Dv]
    state: tuple,     # Moments with shapes [B,Hkv,Dv],[B,Hkv,D,Dv],
                      # [B,Hkv,D,D,Dv],[B,Hkv],[B,Hkv,D],[B,Hkv,D,D]
    *,
    p: int = 2,
    denom_eps: float = 1e-6,
    interpret: bool = False,
    out_dtype=None,
    bm: int | None = None,
    grid: str | None = None,
):
    b, hq, _, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    out_dtype = out_dtype or q.dtype
    m0, m1, m2, g0, g1, g2 = state
    bh = b * hkv

    acc = jnp.promote_types(q.dtype, jnp.float32)
    qr = q.reshape(b, hkv, g, d).reshape(bh, g, d)
    kr = k.reshape(bh, 1, d)
    vr = v.reshape(bh, 1, dv)
    m0r = m0.reshape(bh, 1, dv).astype(acc)
    m1r = m1.reshape(bh, d, dv).astype(acc)
    if p >= 2:
        m2r = m2.reshape(bh, d * d, dv).astype(acc)
    else:
        m2r = jnp.zeros((bh, 1, dv), acc)  # dummy, passed through
    g0r = g0.reshape(bh, 1, 1).astype(acc)
    g1r = g1.reshape(bh, 1, d).astype(acc)
    g2r = g2.reshape(bh, d, d).astype(acc)

    if bm is None:
        bm = pick_bm(d)
    if d % bm:
        raise ValueError(f"bm={bm} must divide D={d}")
    if grid is None:
        grid = "parallel"
    if grid not in ("parallel", "arbitrary"):
        raise ValueError(f"grid={grid!r}; expected 'parallel'|'arbitrary'")
    nmb = d // bm if p >= 2 else 1
    m2_rows = bm * d if p >= 2 else 1

    kernel = functools.partial(_decode_kernel, p=p, bm=bm, nmb=nmb,
                               denom_eps=denom_eps, acc=acc)
    sm = lambda h, mb: (h, 0, 0)          # noqa: E731 small/state blocks
    mm = lambda h, mb: (h, mb, 0)         # noqa: E731 m2 m-blocks
    outs = pl.pallas_call(
        kernel,
        grid=(bh, nmb),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h, mb: (h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda h, mb: (h, 0, 0)),
            pl.BlockSpec((1, 1, dv), lambda h, mb: (h, 0, 0)),
            pl.BlockSpec((1, 1, dv), sm),
            pl.BlockSpec((1, d, dv), sm),
            pl.BlockSpec((1, m2_rows, dv), mm),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ],
        out_specs=[
            pl.BlockSpec((1, g, dv), lambda h, mb: (h, 0, 0)),
            pl.BlockSpec((1, 1, dv), sm),
            pl.BlockSpec((1, d, dv), sm),
            pl.BlockSpec((1, m2_rows, dv), mm),
            pl.BlockSpec((1, 1, 1), sm),
            pl.BlockSpec((1, 1, d), sm),
            pl.BlockSpec((1, d, d), sm),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, dv), out_dtype),
            jax.ShapeDtypeStruct((bh, 1, dv), acc),
            jax.ShapeDtypeStruct((bh, d, dv), acc),
            jax.ShapeDtypeStruct((bh, nmb * m2_rows, dv), acc),
            jax.ShapeDtypeStruct((bh, 1, 1), acc),
            jax.ShapeDtypeStruct((bh, 1, d), acc),
            jax.ShapeDtypeStruct((bh, d, d), acc),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dv), acc),
            pltpu.VMEM((g, 1), acc),
        ],
        input_output_aliases={3: 1, 4: 2, 5: 3, 6: 4, 7: 5, 8: 6},
        # the head axis follows the schedule's `grid` knob; the m-block
        # axis is the sequential m2 stream (carries acc/den scratch)
        compiler_params=tpu_compiler_params((grid, "arbitrary")),
        interpret=interpret,
        name=f"fastmax_decode_p{p}",
    )(qr, kr, vr, m0r, m1r, m2r, g0r, g1r, g2r)

    o, m0n, m1n, m2n, g0n, g1n, g2n = outs
    o = o.reshape(b, hq, 1, dv)
    new_state = (
        m0n.reshape(b, hkv, dv),
        m1n.reshape(b, hkv, d, dv),
        m2n.reshape(b, hkv, d, d, dv) if p >= 2 else m2,
        g0n.reshape(b, hkv),
        g1n.reshape(b, hkv, d),
        g2n.reshape(b, hkv, d, d),
    )
    return o, new_state
