"""jit'd wrappers for the Fastmax Pallas kernels.

Dispatch policy:
  * on TPU: compiled Pallas kernels.
  * elsewhere (this CPU container, tests): interpret=True — the kernel body
    executes in Python/XLA-CPU for bit-level validation of the SAME code
    that Mosaic would compile for TPU.

Training gradients: the kernel forward is paired (via custom_vjp) with the
fused Pallas causal-backward kernel (`fastmax_causal_bwd.py`) implementing
the paper §2.5 reversible-carry recomputation in VMEM. The forward kernel
itself emits the final moment carry as the only extra residual beyond
(q, k, v) — O(D^{p+1}), not O(N D^p), and with no second jnp pass over the
sequence. The jnp chunked backward (`_causal_scan_cg_bwd`) remains wired in
as an interpret-mode oracle, selectable via REPRO_FASTMAX_BWD=jnp.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import fastmax as _fm
from repro.core import hybrid as _hy
from repro.kernels import autotune as _at
from repro.kernels.fastmax_causal import fastmax_causal_pallas
from repro.kernels.fastmax_causal_bwd import fastmax_causal_bwd_pallas
from repro.kernels.fastmax_decode import fastmax_decode_pallas
from repro.kernels.fastmax_noncausal import fastmax_noncausal_pallas
from repro.kernels.hybrid_causal import hybrid_causal_pallas

__all__ = ["fastmax", "fastmax_prefill_kernel", "fastmax_decode",
           "fastmax_bwd", "hybrid", "use_interpret", "use_pallas_bwd"]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lookup(kernel: str, q, k, v, p: int, chunk_size: int):
    """Autotune lookup at trace time (shapes are concrete); returns None
    when REPRO_AUTOTUNE is off — the kernels then use their own pick_*
    defaults, byte-identical to an autotune-free build."""
    return _at.lookup_schedule(
        kernel, n=q.shape[2], d=q.shape[3], dv=v.shape[-1],
        g=q.shape[1] // k.shape[1], p=p, dtype=q.dtype,
        chunk_size=chunk_size)


def _causal_kwargs(sched, chunk_size: int) -> dict:
    """Schedule → fastmax_causal(_bwd)_pallas kwargs ({} keeps defaults)."""
    if sched is None:
        return {"chunk_size": chunk_size}
    return {"chunk_size": sched.chunk_size, "bm": sched.bm,
            "blk": sched.blk, "grid": sched.grid}


def _nc_kwargs(sched, chunk_size: int) -> dict:
    if sched is None:
        return {"chunk_size": chunk_size}
    return {"chunk_size": sched.chunk_size, "bm": sched.bm,
            "grid": sched.grid}


def use_pallas_bwd() -> bool:
    """Backward schedule: the fused Pallas kernel unless REPRO_FASTMAX_BWD
    selects the jnp §2.5 chunked scan (the equivalence oracle)."""
    return os.environ.get("REPRO_FASTMAX_BWD", "pallas").lower() != "jnp"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fastmax_causal_trainable(q, k, v, p, chunk_size, denom_eps, interpret,
                              sched_fwd, sched_bwd):
    # sched_fwd/sched_bwd are hashable Schedule records (or None for the
    # untuned defaults) — static nondiff args so fwd and bwd each run
    # their OWN tuned schedule (the moments are plain sums, so the two
    # sides may chunk/block the sequence independently)
    return fastmax_causal_pallas(
        q, k, v, p=p, denom_eps=denom_eps, interpret=interpret,
        **_causal_kwargs(sched_fwd, chunk_size))


def _fc_fwd(q, k, v, p, chunk_size, denom_eps, interpret, sched_fwd,
            sched_bwd):
    # the forward kernel emits its own final carry (m-major moments) — the
    # only residual the reversible backward needs beyond (q, k, v):
    # O(D^{p+1}) bytes, and no extra jnp pass over the full sequence (the
    # former `compute_moments` call here spiked peak memory at long N).
    o, state = fastmax_causal_pallas(
        q, k, v, p=p, denom_eps=denom_eps, interpret=interpret,
        return_state=True, **_causal_kwargs(sched_fwd, chunk_size))
    if p < 2:
        # don't hold the [B,Hkv,D,D,Dv] zeros placeholder live as a
        # residual — at p=1 both backwards ignore/rebuild it
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, state)


def _fc_bwd(p, chunk_size, denom_eps, interpret, sched_fwd, sched_bwd, res,
            do):
    q, k, v, state = res
    return fastmax_bwd(q, k, v, state, do, p=p, chunk_size=chunk_size,
                       denom_eps=denom_eps, interpret=interpret,
                       schedule=sched_bwd)


def fastmax_bwd(q, k, v, state, do, *, p: int = 2, chunk_size: int = 128,
                denom_eps: float = 1e-6, interpret: bool | None = None,
                schedule=None, return_dstate: bool = False):
    """Causal fastmax backward on the kernel-emitted final carry.

    Returns (dq, dk, dv). The Dv-blocked fused Pallas kernel by default;
    REPRO_FASTMAX_BWD=jnp reroutes to the jnp §2.5 chunked reverse scan
    (the equivalence oracle and escape hatch). `state` may carry None for
    m2 at p < 2 (the custom_vjp residual drops the zeros placeholder).

    `return_dstate=True` appends the cotangent of the scan's initial carry
    (moment-layout tuple) — dC_i for a context-parallel shard whose forward
    was seeded; supported by BOTH backends so CP grads stay oracle-testable.

    Also the per-shard backward of the feature-TP trainable path
    (`repro.kernels.sharded`): on a Dv shard of (v, do, m-moments) with the
    full g-moments, every emitted dq/dk term is the shard's exact partial
    (the same additive-over-Dv decomposition the in-kernel blocking uses),
    so one psum per launch reassembles the full gradients — and that holds
    for BOTH backends here, keeping the jnp oracle comparable shard-local.
    """
    if interpret is None:
        interpret = use_interpret()
    if use_pallas_bwd():
        if schedule is None:
            schedule = _lookup("causal_bwd", q, k, v, p, chunk_size)
        return fastmax_causal_bwd_pallas(
            q, k, v, state, do, p=p, denom_eps=denom_eps,
            interpret=interpret, return_dstate=return_dstate,
            **_causal_kwargs(schedule, chunk_size))
    # jnp oracle: the §2.5 chunked reverse scan on the same kernel-emitted
    # carry (kept for equivalence testing and as an escape hatch)
    if state[2] is None or p < 2:
        d, dv = q.shape[-1], v.shape[-1]
        m2 = jnp.zeros(k.shape[:2] + (d, d, dv), state[0].dtype)
        state = tuple(state[:2]) + (m2,) + tuple(state[3:])
    return _fm._causal_scan_cg_bwd(p, chunk_size, denom_eps, False,
                                   (q, k, v, _fm.Moments(*state)), do,
                                   return_dstate=return_dstate)


_fastmax_causal_trainable.defvjp(_fc_fwd, _fc_bwd)


def fastmax(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool | None = None,
    schedule=None,
) -> jnp.ndarray:
    """Kernel-backed fastmax on pre-normalized q̂/k̂ (GQA-aware).

    `schedule` forces one `autotune.Schedule` on every launch (tests);
    None consults the autotuner per kernel — which itself returns None
    (the untuned `pick_*` defaults) unless REPRO_AUTOTUNE enables it.
    """
    if interpret is None:
        interpret = use_interpret()
    if causal:
        sf = schedule if schedule is not None else _lookup(
            "causal_fwd", q, k, v, p, chunk_size)
        sb = schedule if schedule is not None else _lookup(
            "causal_bwd", q, k, v, p, chunk_size)
        return _fastmax_causal_trainable(
            q, k, v, p, chunk_size, denom_eps, interpret, sf, sb)
    if schedule is None:
        schedule = _lookup("noncausal", q, k, v, p, chunk_size)
    return _fastmax_noncausal_trainable(
        q, k, v, p, chunk_size, denom_eps, interpret, schedule)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fastmax_noncausal_trainable(q, k, v, p, chunk_size, denom_eps,
                                 interpret, sched):
    return fastmax_noncausal_pallas(
        q, k, v, p=p, denom_eps=denom_eps, interpret=interpret,
        **_nc_kwargs(sched, chunk_size))


def _fnc_fwd(q, k, v, p, chunk_size, denom_eps, interpret, sched):
    o = fastmax_noncausal_pallas(
        q, k, v, p=p, denom_eps=denom_eps, interpret=interpret,
        **_nc_kwargs(sched, chunk_size))
    return o, (q, k, v)


def _fnc_bwd(p, chunk_size, denom_eps, interpret, sched, res, do):
    # the two-phase noncausal kernel has no fused backward: grads come from
    # autodiff of the jnp moment path — ONE global moment sum, so residuals
    # are O(N D^p) scan chunks, never O(N^2) scores. Mathematically the
    # same function as the kernel forward (encoder attention stays
    # kernel-routed under training instead of rerouting the forward too).
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _fm.fastmax_noncausal(
            q_, k_, v_, p=p, denom_eps=denom_eps,
            chunk_size=max(chunk_size, 512)),
        q, k, v)
    return vjp(do)


_fastmax_noncausal_trainable.defvjp(_fnc_fwd, _fnc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _hybrid_causal_trainable(q, k, v, p, window, chunk_size, denom_eps,
                             interpret, sched_fwd):
    return hybrid_causal_pallas(
        q, k, v, p=p, window=window, denom_eps=denom_eps,
        interpret=interpret, **_causal_kwargs(sched_fwd, chunk_size))


def _hc_fwd(q, k, v, p, window, chunk_size, denom_eps, interpret, sched_fwd):
    # like fastmax: the forward kernel emits the final moment carry as the
    # only residual beyond (q, k, v) — the band needs no carry, its
    # residuals (the previous chunk's k/v) are rebuilt by shifting in the
    # reverse scan
    o, state = hybrid_causal_pallas(
        q, k, v, p=p, window=window, denom_eps=denom_eps,
        interpret=interpret, return_state=True,
        **_causal_kwargs(sched_fwd, chunk_size))
    if p < 2:
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, state)


def _hc_bwd(p, window, chunk_size, denom_eps, interpret, sched_fwd, res, do):
    q, k, v, state = res
    if state[2] is None or p < 2:
        d, dv = q.shape[-1], v.shape[-1]
        m2 = jnp.zeros(k.shape[:2] + (d, d, dv), state[0].dtype)
        state = tuple(state[:2]) + (m2,) + tuple(state[3:])
    # the backward must re-chunk exactly like the forward: w_eff depends on
    # the chunk length, so a tuned forward schedule pins the reverse scan's
    # chunk size too
    cs = sched_fwd.chunk_size if sched_fwd is not None else chunk_size
    return _hy.hybrid_bwd_scan(
        q, k, v, _fm.Moments(*state), do, p=p, window=window,
        chunk_size=cs, denom_eps=denom_eps)


_hybrid_causal_trainable.defvjp(_hc_fwd, _hc_bwd)


def hybrid(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    window: int = 64,
    causal: bool = True,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool | None = None,
    schedule=None,
) -> jnp.ndarray:
    """Kernel-backed hybrid near/far-field attention on pre-normalized
    q̂/k̂ (causal only). Forward is the fused Pallas launch
    (`hybrid_causal.py`); backward is the jnp §2.5 reverse scan extended
    with band residuals, seeded by the kernel-emitted carry. w_eff=0
    delegates to the fastmax pair for bitwise parity."""
    if not causal:
        raise ValueError("hybrid kernels are causal-only")
    if interpret is None:
        interpret = use_interpret()
    if _hy.effective_window(window, chunk_size) == 0:
        return fastmax(q, k, v, p=p, causal=True, chunk_size=chunk_size,
                       denom_eps=denom_eps, interpret=interpret,
                       schedule=schedule)
    sf = schedule if schedule is not None else _lookup(
        "hybrid_fwd", q, k, v, p, chunk_size)
    return _hybrid_causal_trainable(
        q, k, v, p, window, chunk_size, denom_eps, interpret, sf)


def fastmax_prefill_kernel(
    q, k, v, *, p: int = 2, chunk_size: int = 128, denom_eps: float = 1e-6,
    kv_mask=None, interpret: bool | None = None, schedule=None,
    init_state=None,
):
    """Kernel-backed causal prefill on pre-normalized q̂/k̂ (distinct from
    the jnp `repro.core.decode_state.fastmax_prefill`, which normalizes
    internally and returns a `Moments` NamedTuple).

    Returns (o, state): the final moment carry is emitted by the forward
    kernel itself (no recompute pass), in the layout `fastmax_decode`
    consumes natively — the prefill→decode handoff is one kernel launch.
    `init_state` seeds the scan with an existing carry (moment tuple) —
    tokens already folded by earlier context-parallel shards or an earlier
    resumable-prefill call; the outputs are then the exact causal
    continuation and the returned state includes the seed.
    """
    if interpret is None:
        interpret = use_interpret()
    if schedule is None:
        schedule = _lookup("causal_fwd", q, k, v, p, chunk_size)
    return fastmax_causal_pallas(
        q, k, v, kv_mask, p=p, denom_eps=denom_eps, interpret=interpret,
        return_state=True, init_state=init_state,
        **_causal_kwargs(schedule, chunk_size))


def fastmax_decode(
    q, k, v, state, *, p: int = 2, denom_eps: float = 1e-6,
    interpret: bool | None = None, schedule=None,
):
    """Kernel-backed single-token decode step on moment-tuple state."""
    if interpret is None:
        interpret = use_interpret()
    if schedule is None:
        schedule = _lookup("decode", q, k, v, p, 128)
    dk = {} if schedule is None else {"bm": schedule.bm,
                                      "grid": schedule.grid}
    return fastmax_decode_pallas(
        q, k, v, tuple(state), p=p, denom_eps=denom_eps, interpret=interpret,
        **dk)
