"""jit'd wrappers for the Fastmax Pallas kernels.

Dispatch policy:
  * on TPU: compiled Pallas kernels.
  * elsewhere (this CPU container, tests): interpret=True — the kernel body
    executes in Python/XLA-CPU for bit-level validation of the SAME code
    that Mosaic would compile for TPU.

Training gradients: the kernel forward is paired (via custom_vjp) with the
memory-reduced chunked backward from `repro.core.fastmax` (paper §2.5) — the
backward recomputes moments reversibly instead of storing per-chunk state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fastmax as _fm
from repro.kernels.fastmax_causal import fastmax_causal_pallas
from repro.kernels.fastmax_decode import fastmax_decode_pallas
from repro.kernels.fastmax_noncausal import fastmax_noncausal_pallas

__all__ = ["fastmax", "fastmax_decode", "use_interpret"]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fastmax_causal_trainable(q, k, v, p, chunk_size, denom_eps, interpret):
    return fastmax_causal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret)


def _fc_fwd(q, k, v, p, chunk_size, denom_eps, interpret):
    o = fastmax_causal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret)
    # full-sequence moments: the only extra residual the reversible
    # backward needs beyond (q, k, v) — O(D^{p+1}), not O(N D^p).
    mom = _fm.compute_moments(k, v, p=p)
    return o, (q, k, v, mom)


def _fc_bwd(p, chunk_size, denom_eps, interpret, res, do):
    q, k, v, final = res
    return _fm._causal_scan_cg_bwd(p, chunk_size, denom_eps, False,
                                   (q, k, v, final), do)


_fastmax_causal_trainable.defvjp(_fc_fwd, _fc_bwd)


def fastmax(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed fastmax on pre-normalized q̂/k̂ (GQA-aware)."""
    if interpret is None:
        interpret = use_interpret()
    if causal:
        return _fastmax_causal_trainable(
            q, k, v, p, chunk_size, denom_eps, interpret)
    return fastmax_noncausal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret)


def fastmax_decode(
    q, k, v, state, *, p: int = 2, denom_eps: float = 1e-6,
    interpret: bool | None = None,
):
    """Kernel-backed single-token decode step on moment-tuple state."""
    if interpret is None:
        interpret = use_interpret()
    return fastmax_decode_pallas(
        q, k, v, tuple(state), p=p, denom_eps=denom_eps, interpret=interpret)
