"""jit'd wrappers for the Fastmax Pallas kernels.

Dispatch policy:
  * on TPU: compiled Pallas kernels.
  * elsewhere (this CPU container, tests): interpret=True — the kernel body
    executes in Python/XLA-CPU for bit-level validation of the SAME code
    that Mosaic would compile for TPU.

Training gradients: the kernel forward is paired (via custom_vjp) with the
fused Pallas causal-backward kernel (`fastmax_causal_bwd.py`) implementing
the paper §2.5 reversible-carry recomputation in VMEM. The forward kernel
itself emits the final moment carry as the only extra residual beyond
(q, k, v) — O(D^{p+1}), not O(N D^p), and with no second jnp pass over the
sequence. The jnp chunked backward (`_causal_scan_cg_bwd`) remains wired in
as an interpret-mode oracle, selectable via REPRO_FASTMAX_BWD=jnp.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import fastmax as _fm
from repro.kernels.fastmax_causal import fastmax_causal_pallas
from repro.kernels.fastmax_causal_bwd import fastmax_causal_bwd_pallas
from repro.kernels.fastmax_decode import fastmax_decode_pallas
from repro.kernels.fastmax_noncausal import fastmax_noncausal_pallas

__all__ = ["fastmax", "fastmax_prefill_kernel", "fastmax_decode",
           "fastmax_bwd", "use_interpret", "use_pallas_bwd"]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas_bwd() -> bool:
    """Backward schedule: the fused Pallas kernel unless REPRO_FASTMAX_BWD
    selects the jnp §2.5 chunked scan (the equivalence oracle)."""
    return os.environ.get("REPRO_FASTMAX_BWD", "pallas").lower() != "jnp"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fastmax_causal_trainable(q, k, v, p, chunk_size, denom_eps, interpret):
    return fastmax_causal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret)


def _fc_fwd(q, k, v, p, chunk_size, denom_eps, interpret):
    # the forward kernel emits its own final carry (m-major moments) — the
    # only residual the reversible backward needs beyond (q, k, v):
    # O(D^{p+1}) bytes, and no extra jnp pass over the full sequence (the
    # former `compute_moments` call here spiked peak memory at long N).
    o, state = fastmax_causal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret, return_state=True)
    if p < 2:
        # don't hold the [B,Hkv,D,D,Dv] zeros placeholder live as a
        # residual — at p=1 both backwards ignore/rebuild it
        state = state[:2] + (None,) + state[3:]
    return o, (q, k, v, state)


def _fc_bwd(p, chunk_size, denom_eps, interpret, res, do):
    q, k, v, state = res
    return fastmax_bwd(q, k, v, state, do, p=p, chunk_size=chunk_size,
                       denom_eps=denom_eps, interpret=interpret)


def fastmax_bwd(q, k, v, state, do, *, p: int = 2, chunk_size: int = 128,
                denom_eps: float = 1e-6, interpret: bool | None = None):
    """Causal fastmax backward on the kernel-emitted final carry.

    Returns (dq, dk, dv). The Dv-blocked fused Pallas kernel by default;
    REPRO_FASTMAX_BWD=jnp reroutes to the jnp §2.5 chunked reverse scan
    (the equivalence oracle and escape hatch). `state` may carry None for
    m2 at p < 2 (the custom_vjp residual drops the zeros placeholder).

    Also the per-shard backward of the feature-TP trainable path
    (`repro.kernels.sharded`): on a Dv shard of (v, do, m-moments) with the
    full g-moments, every emitted dq/dk term is the shard's exact partial
    (the same additive-over-Dv decomposition the in-kernel blocking uses),
    so one psum per launch reassembles the full gradients — and that holds
    for BOTH backends here, keeping the jnp oracle comparable shard-local.
    """
    if interpret is None:
        interpret = use_interpret()
    if use_pallas_bwd():
        return fastmax_causal_bwd_pallas(
            q, k, v, state, do, p=p, chunk_size=chunk_size,
            denom_eps=denom_eps, interpret=interpret)
    # jnp oracle: the §2.5 chunked reverse scan on the same kernel-emitted
    # carry (kept for equivalence testing and as an escape hatch)
    if state[2] is None or p < 2:
        d, dv = q.shape[-1], v.shape[-1]
        m2 = jnp.zeros(k.shape[:2] + (d, d, dv), state[0].dtype)
        state = tuple(state[:2]) + (m2,) + tuple(state[3:])
    return _fm._causal_scan_cg_bwd(p, chunk_size, denom_eps, False,
                                   (q, k, v, _fm.Moments(*state)), do)


_fastmax_causal_trainable.defvjp(_fc_fwd, _fc_bwd)


def fastmax(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    p: int = 2,
    causal: bool = False,
    chunk_size: int = 128,
    denom_eps: float = 1e-6,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed fastmax on pre-normalized q̂/k̂ (GQA-aware)."""
    if interpret is None:
        interpret = use_interpret()
    if causal:
        return _fastmax_causal_trainable(
            q, k, v, p, chunk_size, denom_eps, interpret)
    return fastmax_noncausal_pallas(
        q, k, v, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret)


def fastmax_prefill_kernel(
    q, k, v, *, p: int = 2, chunk_size: int = 128, denom_eps: float = 1e-6,
    kv_mask=None, interpret: bool | None = None,
):
    """Kernel-backed causal prefill on pre-normalized q̂/k̂ (distinct from
    the jnp `repro.core.decode_state.fastmax_prefill`, which normalizes
    internally and returns a `Moments` NamedTuple).

    Returns (o, state): the final moment carry is emitted by the forward
    kernel itself (no recompute pass), in the layout `fastmax_decode`
    consumes natively — the prefill→decode handoff is one kernel launch.
    """
    if interpret is None:
        interpret = use_interpret()
    return fastmax_causal_pallas(
        q, k, v, kv_mask, p=p, chunk_size=chunk_size, denom_eps=denom_eps,
        interpret=interpret, return_state=True)


def fastmax_decode(
    q, k, v, state, *, p: int = 2, denom_eps: float = 1e-6,
    interpret: bool | None = None,
):
    """Kernel-backed single-token decode step on moment-tuple state."""
    if interpret is None:
        interpret = use_interpret()
    return fastmax_decode_pallas(
        q, k, v, tuple(state), p=p, denom_eps=denom_eps, interpret=interpret)
