"""Continuous-batching serving engine (FAST's O(1)-state decode, served).

    engine.ServeEngine   submit()/step()/stream()/cancel(): mixed
                         chunked-prefill + batched-decode ticks over a
                         fixed slot pool, with admission control,
                         deadlines, non-finite quarantine, and a watchdog
    slots.SlotManager    slot-indexed decode state, O(1) admit/evict
    scheduler.Scheduler  fcfs / longest-prefill-first admission over a
                         bounded queue (depth + prompt-token budget)
    prefix_cache         prompt-prefix snapshot reuse (LRU byte budget)
    errors               request lifecycle statuses + structured failures
    faults.FaultInjector deterministic chaos harness (`make test-faults`)
"""
from repro.serve.engine import FinishedRequest, ServeEngine  # noqa: F401
from repro.serve.errors import (  # noqa: F401
    EngineOverloaded,
    EngineStalled,
    RequestStatus,
    RequestTimeout,
    ServeError,
    SlotQuarantined,
)
from repro.serve.faults import FaultInjector  # noqa: F401
from repro.serve.prefix_cache import PrefixCache  # noqa: F401
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
from repro.serve.slots import SlotManager  # noqa: F401

__all__ = ["ServeEngine", "FinishedRequest", "PrefixCache", "Request",
           "Scheduler", "SlotManager", "RequestStatus", "ServeError",
           "EngineOverloaded", "EngineStalled", "RequestTimeout",
           "SlotQuarantined", "FaultInjector"]
