"""Continuous-batching serving engine (FAST's O(1)-state decode, served).

    engine.ServeEngine   submit()/step()/stream(): mixed chunked-prefill +
                         batched-decode ticks over a fixed slot pool
    slots.SlotManager    slot-indexed decode state, O(1) admit/evict
    scheduler.Scheduler  fcfs / longest-prefill-first admission
    prefix_cache         prompt-prefix snapshot reuse (LRU byte budget)
"""
from repro.serve.engine import FinishedRequest, ServeEngine  # noqa: F401
from repro.serve.prefix_cache import PrefixCache  # noqa: F401
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
from repro.serve.slots import SlotManager  # noqa: F401

__all__ = ["ServeEngine", "FinishedRequest", "PrefixCache", "Request",
           "Scheduler", "SlotManager"]
