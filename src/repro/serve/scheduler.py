"""Admission scheduling for the continuous-batching engine.

Requests wait in a host-side queue until a slot frees up. Two policies:

  fcfs  -> strict arrival order.
  lpf   -> longest-prefill-first: admit the queued request with the most
           prompt tokens, so the big prefills start streaming chunks early
           and short requests fill the decode batch around them. Guarded by
           `max_wait`: once the oldest request has waited that many engine
           ticks it is admitted next regardless (no starvation).

The scheduler is pure host bookkeeping — it never touches device state.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

__all__ = ["Request", "Scheduler", "POLICIES"]

POLICIES = ("fcfs", "lpf")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    callback: Optional[Callable[[int, int], Any]] = None  # (rid, token)
    submit_tick: int = 0               # engine tick at submission
    submit_time: float = 0.0           # wall clock (load-gen latency stats)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class Scheduler:
    def __init__(self, policy: str = "fcfs", *, max_wait: int = 64):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.policy = policy
        self.max_wait = int(max_wait)
        self._q: deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> None:
        self._q.append(req)

    def pop(self, tick: int) -> Optional[Request]:
        """Next request to admit, or None if the queue is empty."""
        if not self._q:
            return None
        if self.policy == "fcfs":
            return self._q.popleft()
        # lpf: oldest-first once it has starved past max_wait
        oldest = self._q[0]
        if tick - oldest.submit_tick >= self.max_wait:
            return self._q.popleft()
        i = max(range(len(self._q)),
                key=lambda j: (len(self._q[j].prompt), -j))
        req = self._q[i]
        del self._q[i]
        return req
