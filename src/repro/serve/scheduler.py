"""Admission scheduling for the continuous-batching engine.

Requests wait in a host-side queue until a slot frees up. Two policies:

  fcfs  -> strict arrival order.
  lpf   -> longest-prefill-first: admit the queued request with the most
           prompt tokens, so the big prefills start streaming chunks early
           and short requests fill the decode batch around them. Guarded by
           `max_wait`: once the oldest request has waited that many engine
           ticks it is admitted next regardless (no starvation).

The queue is BOUNDED: `max_depth` caps how many requests may wait and
`max_queued_tokens` caps the sum of their prompt lengths. `push` raises
`EngineOverloaded` past either bound — the engine's backpressure signal —
so memory is bounded by configuration, not by arrival rate. Under
sustained saturation the engine additionally calls `shed()` to drop the
newest/largest waiter (graceful degradation: predictable victims instead
of unbounded latency for everyone).

The scheduler is pure host bookkeeping — it never touches device state.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, List, Optional

import numpy as np

from repro.serve.errors import EngineOverloaded, RequestStatus

__all__ = ["Request", "Scheduler", "POLICIES"]

POLICIES = ("fcfs", "lpf")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    callback: Optional[Callable[[int, int], Any]] = None  # (rid, token)
    submit_tick: int = 0               # engine tick at submission
    submit_time: float = 0.0           # wall clock (load-gen latency stats)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # robustness lane (see serve/errors.py)
    status: RequestStatus = RequestStatus.QUEUED
    error: Optional[str] = None        # diagnostic on non-FINISHED terminals
    ttft_deadline: Optional[float] = None  # seconds from submit to token #1
    deadline: Optional[float] = None       # seconds from submit to finish


class Scheduler:
    def __init__(self, policy: str = "fcfs", *, max_wait: int = 64,
                 max_depth: int = 0, max_queued_tokens: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.policy = policy
        self.max_wait = int(max_wait)
        self.max_depth = int(max_depth)              # 0 = unbounded
        self.max_queued_tokens = int(max_queued_tokens)  # 0 = unbounded
        self.queued_tokens = 0
        self._q: deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> None:
        """Enqueue, or raise `EngineOverloaded` past the depth/token bound
        (the queue is left unchanged — rejection has no side effects)."""
        if self.max_depth and len(self._q) >= self.max_depth:
            raise EngineOverloaded(
                f"queue full: {len(self._q)} requests waiting "
                f"(max_queue={self.max_depth})")
        if self.max_queued_tokens and \
                self.queued_tokens + len(req.prompt) > self.max_queued_tokens:
            raise EngineOverloaded(
                f"queued prompt-token budget exhausted: {self.queued_tokens} "
                f"+ {len(req.prompt)} > {self.max_queued_tokens}")
        self._q.append(req)
        self.queued_tokens += len(req.prompt)

    def _take(self, i: int) -> Request:
        req = self._q[i]
        del self._q[i]
        self.queued_tokens -= len(req.prompt)
        return req

    def pop(self, tick: int) -> Optional[Request]:
        """Next request to admit, or None if the queue is empty."""
        if not self._q:
            return None
        if self.policy == "fcfs":
            return self._take(0)
        # lpf: oldest-first once it has starved past max_wait
        if tick - self._q[0].submit_tick >= self.max_wait:
            return self._take(0)
        i = max(range(len(self._q)),
                key=lambda j: (len(self._q[j].prompt), -j))
        return self._take(i)

    def remove(self, rid: int) -> Optional[Request]:
        """Pull a specific queued request out (cancellation)."""
        for i, req in enumerate(self._q):
            if req.rid == rid:
                return self._take(i)
        return None

    def shed(self) -> Optional[Request]:
        """Drop the newest-largest waiter (load shedding under sustained
        saturation): among the queued requests, the one with the longest
        prompt, ties broken newest-first — the victim that frees the most
        budget while hurting the oldest waiters least."""
        if not self._q:
            return None
        i = max(range(len(self._q)),
                key=lambda j: (len(self._q[j].prompt), j))
        return self._take(i)

    def take_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose TTFT or total
        deadline has already expired (queued requests have no first token
        yet, so both deadlines apply)."""
        out = []
        for i in range(len(self._q) - 1, -1, -1):
            req = self._q[i]
            waited = now - req.submit_time
            limit = min((d for d in (req.ttft_deadline, req.deadline)
                         if d is not None), default=None)
            if limit is not None and waited > limit:
                out.append(self._take(i))
        out.reverse()                  # oldest first, like arrival order
        return out
