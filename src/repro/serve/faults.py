"""Deterministic fault injection for the serving engine (chaos tier).

Production failure modes are rehearsed here on purpose, not discovered in
production: fastmax's unnormalized moment sums overflow low precision at
long context (a *paper-specific* hazard — NaN in one slot's moments must
never take down the pool), user callbacks raise, ticks stall, and traffic
bursts past capacity. Every fault is scheduled by ENGINE TICK, so chaos
runs are exactly reproducible: the same script injects the same fault at
the same point in the token stream on every run.

    inj = FaultInjector()
    inj.nan_into_slot(tick=12, slot=1)        # poison one slot's state
    inj.slow_tick(tick=5, seconds=0.05)       # blow the tick budget
    inj.cancel_at(tick=8, rid=3)              # mid-stream cancellation
    eng = ServeEngine(params, cfg, ..., faults=inj)

The engine calls ``inj.apply(engine, tick)`` at the top of every
``step()``; an engine built without ``faults=`` pays nothing. The module
also holds the host-side helpers the injector itself uses (``poison_slot``)
and test utilities (``exploding_callback``, ``burst``) so chaos tests and
the overload benchmark share one vocabulary.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.errors import EngineOverloaded

__all__ = ["FaultInjector", "poison_slot", "exploding_callback", "burst"]


def poison_slot(slots, slot: int, value: float = float("nan")) -> int:
    """Overwrite every floating-point leaf of one slot's decode state with
    `value` (device-side read-modify-write of that slot only). Returns the
    number of leaves poisoned. Integer lanes (cursors, positions) are left
    intact so the fault is purely numerical — exactly what a low-precision
    moment overflow looks like."""
    unit = slots.snapshot(slot)
    n = 0

    def bad(leaf):
        nonlocal n
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            n += 1
            return jnp.full_like(leaf, value)
        return leaf

    unit = jax.tree.map(bad, unit)
    slots.state = slots._write(slots.state, unit,
                               jnp.asarray(slot, jnp.int32))
    return n


def exploding_callback(n: int, exc: Optional[Exception] = None):
    """A per-token callback that raises on its `n`-th invocation — the
    canonical misbehaving-user-code fault. The engine must fail only the
    owning request and keep serving."""
    count = {"i": 0}

    def cb(rid, tok):
        count["i"] += 1
        if count["i"] >= n:
            raise (exc if exc is not None
                   else RuntimeError(f"callback exploded on token #{n}"))

    return cb


def burst(engine, prompts, max_new_tokens: int, **submit_kw
          ) -> Tuple[List[int], int]:
    """Submit a burst of prompts at once, absorbing backpressure: returns
    (admitted rids, number rejected with `EngineOverloaded`). The overload
    benchmark and chaos tests both drive saturation through this."""
    rids, rejected = [], 0
    for p in prompts:
        try:
            rids.append(engine.submit(p, max_new_tokens, **submit_kw))
        except EngineOverloaded:
            rejected += 1
    return rids, rejected


class FaultInjector:
    """Tick-scheduled fault script. Actions registered for tick T run at
    the top of the engine's T-th `step()` (before deadline checks and
    admission), in registration order. `self.log` records what fired and
    when, for assertions."""

    def __init__(self):
        self._at: Dict[int, List[Tuple[str, Callable[[Any], None]]]] = \
            defaultdict(list)
        self.log: List[Tuple[int, str]] = []

    def _schedule(self, tick: int, name: str,
                  fn: Callable[[Any], None]) -> "FaultInjector":
        self._at[int(tick)].append((name, fn))
        return self

    # -- fault vocabulary ----------------------------------------------------

    def nan_into_slot(self, tick: int, slot: int,
                      value: float = float("nan")) -> "FaultInjector":
        """Poison every float leaf of `slot`'s state before tick `tick` —
        the moment-overflow failure the quarantine guard exists for."""
        return self._schedule(
            tick, f"nan_into_slot({slot})",
            lambda eng: poison_slot(eng.slots, slot, value))

    def slow_tick(self, tick: int, seconds: float) -> "FaultInjector":
        """Stall tick `tick` by sleeping on the host — a straggling device,
        a GC pause, a noisy neighbor. Drives the tick-budget watchdog."""
        return self._schedule(tick, f"slow_tick({seconds}s)",
                              lambda eng: time.sleep(seconds))

    def cancel_at(self, tick: int, rid: int) -> "FaultInjector":
        """Cancel request `rid` at tick `tick` (mid-prefill or mid-decode,
        wherever it happens to be)."""
        return self._schedule(tick, f"cancel_at(rid={rid})",
                              lambda eng: eng.cancel(rid))

    def call(self, tick: int, fn: Callable[[Any], None],
             name: str = "call") -> "FaultInjector":
        """Escape hatch: run `fn(engine)` at tick `tick` (wedge a host
        lane, drop a queue entry, whatever the scenario needs)."""
        return self._schedule(tick, name, fn)

    # -- engine hook ---------------------------------------------------------

    def apply(self, engine, tick: int) -> None:
        for name, fn in self._at.pop(int(tick), ()):
            self.log.append((int(tick), name))
            fn(engine)
