"""Continuous-batching serving engine over the unified decode protocol.

One `ServeEngine` owns a `SlotManager` pool of `max_slots` sequences and
advances the whole pool one "tick" at a time. Each tick is ONE jitted
launch that mixes the two kinds of work (3 traces total, keyed by which
parts are present):

  prefill part  -> the next `chunk`-token slice of ONE pending request's
                   prompt runs through `lm_prefill(offset=...)` against
                   that slot's state (read_slot -> prefill -> write_slot).
                   The chunk that completes the prompt also emits the
                   request's FIRST token (argmax of the last valid row).
  decode part   -> every active slot takes one `lm_decode_step` with its
                   own last token and its own position lane; slots that
                   are inactive / mid-prefill ride through the batched
                   compute and are restored by `select_slots`.

All backends route through the same `init_state`/`prefill`/`step`
protocol, so the engine works unchanged for softmax-KV, fastmax (chunked
or kernel), GQA/MQA, and SSM-mixer architectures. Greedy decoding matches
`launch.serve.generate` token-for-token (the parity contract
`tests/test_serve.py` pins for every registered backend).

Chunked prefill decomposition equals `generate()`'s internal scan when
`chunk == cfg.chunk_size` (the default) — for fastmax backends the moment
arithmetic is then bit-identical, not merely close.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, lm_decode_step, lm_prefill
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Request, Scheduler
from repro.serve.slots import SlotManager, read_slot, select_slots, write_slot

__all__ = ["ServeEngine", "FinishedRequest"]


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray            # [n_generated] int32 (includes eos if hit)
    prompt_len: int
    ttft: float                   # submit -> first token (s)
    latency: float                # submit -> finish (s)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 policy: str = "fcfs", chunk: Optional[int] = None,
                 prefix_cache_bytes: int = 0, max_wait: int = 64):
        if cfg.encoder_layers > 0:
            raise NotImplementedError(
                "repro.serve targets decoder-only models; use "
                "launch.serve.generate for encoder-decoder")
        self.params = params
        self.cfg = cfg
        self.eos_id = eos_id
        self.chunk = int(chunk or cfg.chunk_size)
        self.slots = SlotManager(cfg, max_slots, max_len)
        self.scheduler = Scheduler(policy, max_wait=max_wait)
        self.prefix_cache = (PrefixCache(prefix_cache_bytes, chunk=self.chunk)
                             if prefix_cache_bytes > 0 else None)
        # ragged final chunks are right-padded + kv_mask'ed, which only the
        # attention prefill path understands; SSM mixers get an exact-length
        # (retracing) ragged chunk instead
        self._pad_ragged = all(k.split(":")[0] == "attn"
                               for k in cfg.pattern)

        b = self.slots.max_slots
        self._rid: List[Optional[int]] = [None] * b
        self._req: Dict[int, Request] = {}
        self._prompt_len = np.zeros(b, np.int32)
        self._last_token = np.zeros(b, np.int32)
        self._generated: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._prefill_cursor = 0      # round-robin over mid-prefill slots
        self.tick_count = 0
        self.decode_tokens = 0        # decode-part tokens (TPOT accounting)
        self.prefill_tokens = 0
        self.history: List[FinishedRequest] = []   # load-gen latency stats

        self._tick_fn = jax.jit(
            functools.partial(_tick, cfg=cfg, axes=self.slots.axes),
            static_argnames=("do_prefill", "do_decode"))

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *, eos_id=None,
               callback=None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError(
                "empty prompt: at least one token must prefill to produce "
                "the first logits")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.slots.max_len:
            raise ValueError(
                f"prompt {len(prompt)} + gen {max_new_tokens} exceeds "
                f"max_len {self.slots.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.push(Request(
            rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens),
            eos_id=self.eos_id if eos_id is None else eos_id,
            callback=callback, submit_tick=self.tick_count,
            submit_time=time.monotonic()))
        return rid

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + in a slot)."""
        return len(self.scheduler) + sum(r is not None for r in self._rid)

    # -- the tick ------------------------------------------------------------

    def step(self) -> List[FinishedRequest]:
        """Advance the pool by one mixed prefill+decode launch. Returns the
        requests that finished this tick."""
        self.tick_count += 1
        self._admit()

        pre = self._pick_prefill()
        live = self.slots.active & ~self.slots.eos
        do_decode = bool(live.any())
        if pre is None and not do_decode:
            return []

        slot = chunk_tok = kv_mask = off = nvalid = None
        if pre is not None:
            slot, chunk_tok, kv_mask, off, nvalid = pre
        state, first_tok, nxt = self._tick_fn(
            self.params, self.slots.state,
            None if pre is None else jnp.asarray(slot, jnp.int32),
            chunk_tok, kv_mask,
            None if pre is None else jnp.asarray(off, jnp.int32),
            None if pre is None else jnp.asarray(nvalid, jnp.int32),
            None if not do_decode else jnp.asarray(self._last_token),
            None if not do_decode else jnp.asarray(self.slots.position),
            None if not do_decode else jnp.asarray(live),
            do_prefill=pre is not None, do_decode=do_decode)
        self.slots.state = state

        finished: List[FinishedRequest] = []
        if pre is not None:
            self._after_prefill(slot, nvalid, first_tok, finished)
        if do_decode:
            self._after_decode(live, np.asarray(nxt), finished)
        return finished

    def run(self, *, max_ticks: int = 1_000_000) -> Dict[int, np.ndarray]:
        """Drive ticks until every submitted request finished. Returns
        {rid: generated tokens}."""
        done: Dict[int, np.ndarray] = {}
        for _ in range(max_ticks):
            if not self.pending:
                break
            for fin in self.step():
                done[fin.rid] = fin.tokens
        return done

    def stream(self, prompt, max_new_tokens: int, *,
               eos_id=None) -> Iterator[int]:
        """Submit one request and yield its tokens as they are produced
        (other already-submitted requests keep making progress)."""
        box: List[int] = []
        rid = self.submit(prompt, max_new_tokens, eos_id=eos_id,
                          callback=lambda _rid, tok: box.append(tok))
        while True:
            fins = self.step()
            while box:
                yield box.pop(0)
            if any(f.rid == rid for f in fins):
                return

    # -- internals -----------------------------------------------------------

    def _admit(self) -> None:
        for slot in range(self.slots.max_slots):
            if self._rid[slot] is not None:
                continue
            req = self.scheduler.pop(self.tick_count)
            if req is None:
                return
            offset, snap = (0, None)
            if self.prefix_cache is not None:
                offset, snap = self.prefix_cache.lookup(req.prompt)
            self.slots.admit(slot, unit_state=snap, position=offset)
            self._rid[slot] = req.rid
            self._req[req.rid] = req
            self._prompt_len[slot] = len(req.prompt)
            self._generated[req.rid] = []

    def _pick_prefill(self):
        """Next slot still owing prompt tokens -> its next chunk.

        Round-robin from a persistent cursor, NOT always the lowest slot:
        one tick prefills one chunk, so a lowest-first scan would feed
        slot 0's long prompt to completion while later slots (admitted the
        same tick) wait at position 0 — head-of-line bias that inflates
        their TTFT. The cursor resumes after the last-served slot so
        concurrent prompts interleave chunk-for-chunk."""
        b = self.slots.max_slots
        for i in range(b):
            slot = (self._prefill_cursor + i) % b
            rid = self._rid[slot]
            if rid is None or self.slots.active[slot] or self.slots.eos[slot]:
                continue
            pos = int(self.slots.position[slot])
            plen = int(self._prompt_len[slot])
            if pos >= plen:
                continue
            self._prefill_cursor = (slot + 1) % b
            n = min(self.chunk, plen - pos)
            toks = self._req[rid].prompt[pos:pos + n]
            if n == self.chunk:
                chunk_tok = jnp.asarray(toks[None], jnp.int32)
                kv_mask = None
            elif self._pad_ragged:
                padded = np.zeros(self.chunk, np.int32)
                padded[:n] = toks
                chunk_tok = jnp.asarray(padded[None], jnp.int32)
                kv_mask = jnp.asarray(
                    (np.arange(self.chunk) < n)[None].astype(np.float32))
            else:
                chunk_tok = jnp.asarray(toks[None], jnp.int32)
                kv_mask = None
            return slot, chunk_tok, kv_mask, pos, n

    def _after_prefill(self, slot: int, nvalid: int, first_tok,
                       finished: List[FinishedRequest]) -> None:
        rid = self._rid[slot]
        req = self._req[rid]
        self.slots.position[slot] += nvalid
        self.prefill_tokens += int(nvalid)
        pos = int(self.slots.position[slot])
        plen = int(self._prompt_len[slot])
        if self.prefix_cache is not None and pos % self.chunk == 0:
            self.prefix_cache.insert(req.prompt, pos,
                                     self.slots.snapshot(slot))
        if pos < plen:
            return
        # prompt complete: the prefill logits' last valid row is token #1
        tok = int(np.asarray(first_tok)[0])
        self.slots.active[slot] = True
        self._last_token[slot] = tok
        if req.first_token_time is None:
            req.first_token_time = time.monotonic()
        self._emit(slot, rid, tok, finished)

    def _after_decode(self, live: np.ndarray, nxt: np.ndarray,
                      finished: List[FinishedRequest]) -> None:
        for slot in np.nonzero(live)[0]:
            rid = self._rid[slot]
            tok = int(nxt[slot])
            self.slots.position[slot] += 1
            self._last_token[slot] = tok
            self.decode_tokens += 1
            self._emit(int(slot), rid, tok, finished)

    def _emit(self, slot: int, rid: int, tok: int,
              finished: List[FinishedRequest]) -> None:
        req = self._req[rid]
        self._generated[rid].append(tok)
        if req.callback is not None:
            req.callback(rid, tok)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(self._generated[rid]) >= req.max_new_tokens:
            req.finish_time = time.monotonic()
            fin = FinishedRequest(
                rid=rid,
                tokens=np.asarray(self._generated.pop(rid), np.int32),
                prompt_len=len(req.prompt),
                ttft=req.first_token_time - req.submit_time,
                latency=req.finish_time - req.submit_time)
            self.history.append(fin)
            finished.append(fin)
            self.slots.eos[slot] = True
            self._rid[slot] = None
            del self._req[rid]
            self.slots.evict(slot)


def _tick(params, state, slot, chunk_tok, kv_mask, off, nvalid,
          tokens, positions, live, *, cfg, axes,
          do_prefill: bool, do_decode: bool):
    """One mixed launch: chunked prefill for one slot + a batched decode
    step for the live slots, on the shared pool state. Static
    do_prefill/do_decode flags -> at most 3 traces."""
    first_tok = None
    if do_prefill:
        unit = read_slot(state, slot, axes)
        logits, unit = lm_prefill(params, chunk_tok, cfg, unit,
                                  offset=off, kv_mask=kv_mask)
        last_row = jax.lax.dynamic_index_in_dim(logits, nvalid - 1, axis=1,
                                                keepdims=False)
        first_tok = jnp.argmax(last_row, axis=-1).astype(jnp.int32)
        state = write_slot(state, unit, slot, axes)
    nxt = None
    if do_decode:
        logits, new_state = lm_decode_step(params, state, tokens, cfg,
                                           position=positions)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        state = select_slots(live, new_state, state, axes)
        nxt = jnp.where(live, nxt, tokens)
    return state, first_tok, nxt
