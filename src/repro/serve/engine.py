"""Continuous-batching serving engine over the unified decode protocol.

One `ServeEngine` owns a `SlotManager` pool of `max_slots` sequences and
advances the whole pool one "tick" at a time. Each tick is ONE jitted
launch that mixes the two kinds of work (3 traces total, keyed by which
parts are present):

  prefill part  -> the next `chunk`-token slice of ONE pending request's
                   prompt runs through `lm_prefill(offset=...)` against
                   that slot's state (read_slot -> prefill -> write_slot).
                   The chunk that completes the prompt also emits the
                   request's FIRST token (argmax of the last valid row).
  decode part   -> every active slot takes one `lm_decode_step` with its
                   own last token and its own position lane; slots that
                   are inactive / mid-prefill ride through the batched
                   compute and are restored by `select_slots`.

All backends route through the same `init_state`/`prefill`/`step`
protocol, so the engine works unchanged for softmax-KV, fastmax (chunked
or kernel), GQA/MQA, and SSM-mixer architectures. Greedy decoding matches
`launch.serve.generate` token-for-token (the parity contract
`tests/test_serve.py` pins for every registered backend).

Chunked prefill decomposition equals `generate()`'s internal scan when
`chunk == cfg.chunk_size` (the default) — for fastmax backends the moment
arithmetic is then bit-identical, not merely close.

Robustness layer (`serve/errors.py`, `docs/serving.md`):

  * every request carries a `RequestStatus`; all terminal outcomes
    (FINISHED / FAILED / CANCELLED / TIMED_OUT / REJECTED) are reported
    as `FinishedRequest` records with a diagnostic, never silently lost;
  * `submit()` enforces a bounded queue (depth + prompt-token budget,
    `EngineOverloaded` on overflow) and the engine sheds the
    newest/largest waiters under sustained saturation — memory and
    latency degrade predictably instead of unboundedly;
  * per-request TTFT / total deadlines and `cancel(rid)` free slots
    mid-prefill or mid-decode and drop the request's prefix-cache
    snapshots;
  * a cheap per-tick non-finite guard on emitted logits (fastmax's
    unnormalized moment sums can overflow low precision at long context)
    fails ONLY the poisoned request and quarantines + re-initializes its
    slot; `REPRO_SERVE_CHECK_STATE=1` adds a deep per-tick check over
    every floating decode-state leaf;
  * a watchdog (`repro.ft.StragglerMonitor` underneath) raises
    `EngineStalled` with an engine snapshot on sustained no-progress
    ticks, blown per-tick wall-clock budgets, or `run()` exhausting
    `max_ticks` with requests still pending — the engine never silently
    spins;
  * `stats()` exposes the counters (admitted / rejected / shed /
    timed_out / cancelled / quarantined / failed / finished, queue depth,
    slot occupancy) the load generator and CLI report.

Deterministic chaos for all of the above lives in `serve/faults.py`
(`ServeEngine(..., faults=FaultInjector())`), driven by
`tests/test_serve_faults.py` (`make test-faults`).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import StragglerMonitor
from repro.models.transformer import ModelConfig, lm_decode_step, lm_prefill
from repro.serve.errors import (TERMINAL_STATUSES, EngineOverloaded,
                                EngineStalled, RequestStatus)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Request, Scheduler
from repro.serve.slots import SlotManager, read_slot, select_slots, write_slot

__all__ = ["ServeEngine", "FinishedRequest"]

# status -> stats() counter bumped when a request reaches that terminal
_TERMINAL_COUNTER = {
    RequestStatus.FINISHED: "finished",
    RequestStatus.FAILED: "failed",
    RequestStatus.CANCELLED: "cancelled",
    RequestStatus.TIMED_OUT: "timed_out",
    RequestStatus.REJECTED: "shed",
}


def _check_eos_id(eos) -> Optional[int]:
    """eos_id must be a non-negative integer token id (bool is an int
    subclass and always a bug here, so it is rejected explicitly)."""
    if eos is None:
        return None
    if isinstance(eos, bool) or not isinstance(eos, (int, np.integer)):
        raise ValueError(
            f"eos_id must be an integer token id, got "
            f"{type(eos).__name__}: {eos!r}")
    if eos < 0:
        raise ValueError(f"eos_id must be non-negative, got {eos}")
    return int(eos)


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray            # [n_generated] int32 (includes eos if hit)
    prompt_len: int
    ttft: Optional[float]         # submit -> first token (s); None if never
    latency: float                # submit -> terminal state (s)
    status: RequestStatus = RequestStatus.FINISHED
    error: Optional[str] = None   # diagnostic on non-FINISHED terminals

    @property
    def ok(self) -> bool:
        return self.status is RequestStatus.FINISHED


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 policy: str = "fcfs", chunk: Optional[int] = None,
                 prefix_cache_bytes: int = 0, max_wait: int = 64,
                 max_queue: int = 256, max_queue_tokens: int = 0,
                 shed_after: int = 64, tick_budget_s: Optional[float] = None,
                 stall_ticks: int = 64, faults=None):
        if cfg.encoder_layers > 0:
            raise NotImplementedError(
                "repro.serve targets decoder-only models; use "
                "launch.serve.generate for encoder-decoder")
        self.params = params
        self.cfg = cfg
        self.eos_id = _check_eos_id(eos_id)
        self.chunk = int(chunk or cfg.chunk_size)
        self.slots = SlotManager(cfg, max_slots, max_len)
        self.scheduler = Scheduler(policy, max_wait=max_wait,
                                   max_depth=max_queue,
                                   max_queued_tokens=max_queue_tokens)
        self.prefix_cache = (PrefixCache(prefix_cache_bytes, chunk=self.chunk)
                             if prefix_cache_bytes > 0 else None)
        # ragged final chunks are right-padded + kv_mask'ed, which only the
        # attention prefill path understands; SSM mixers get an exact-length
        # (retracing) ragged chunk instead
        self._pad_ragged = all(k.split(":")[0] == "attn"
                               for k in cfg.pattern)

        b = self.slots.max_slots
        self._rid: List[Optional[int]] = [None] * b
        self._req: Dict[int, Request] = {}
        self._prompt_len = np.zeros(b, np.int32)
        self._last_token = np.zeros(b, np.int32)
        self._generated: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._prefill_cursor = 0      # round-robin over mid-prefill slots
        self.tick_count = 0
        self.decode_tokens = 0        # decode-part tokens (TPOT accounting)
        self.prefill_tokens = 0
        self.history: List[FinishedRequest] = []   # load-gen latency stats
        self.statuses: Dict[int, RequestStatus] = {}  # rid -> last status

        # robustness knobs
        self.shed_after = int(shed_after)     # saturated ticks before shed
        self.tick_budget_s = tick_budget_s    # wall-clock budget per tick
        self.stall_ticks = int(stall_ticks)   # no-progress ticks -> stalled
        self.faults = faults                  # serve.faults.FaultInjector
        self.monitor = StragglerMonitor()     # tick-time stats (ft idiom)
        self.counters: Dict[str, int] = {
            "admitted": 0, "rejected": 0, "shed": 0, "timed_out": 0,
            "cancelled": 0, "quarantined": 0, "failed": 0, "finished": 0}
        self._saturated_ticks = 0
        self._stall_strikes = 0
        self._budget_strikes = 0
        self._budget_patience = 3
        self._check_state = os.environ.get("REPRO_SERVE_CHECK_STATE") == "1"
        self._finite_fn = None                # lazily jitted deep check

        self._tick_fn = jax.jit(
            functools.partial(_tick, cfg=cfg, axes=self.slots.axes),
            static_argnames=("do_prefill", "do_decode"))

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *, eos_id=None,
               callback=None, ttft_deadline: Optional[float] = None,
               deadline: Optional[float] = None) -> int:
        """Enqueue one request. Raises `ValueError` on malformed input and
        `EngineOverloaded` when the bounded queue refuses admission (the
        engine state is unchanged in both cases)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError(
                "empty prompt: at least one token must prefill to produce "
                "the first logits")
        if len(prompt) > self.slots.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds the model context "
                f"(engine max_len {self.slots.max_len})")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.slots.max_len:
            raise ValueError(
                f"prompt {len(prompt)} + gen {max_new_tokens} exceeds "
                f"max_len {self.slots.max_len}")
        eos = self.eos_id if eos_id is None else _check_eos_id(eos_id)
        for name, d in (("ttft_deadline", ttft_deadline),
                        ("deadline", deadline)):
            if d is not None and d < 0:
                raise ValueError(f"{name} must be >= 0 seconds, got {d}")
        req = Request(
            rid=self._next_rid, prompt=prompt,
            max_new_tokens=int(max_new_tokens), eos_id=eos,
            callback=callback, submit_tick=self.tick_count,
            submit_time=time.monotonic(),
            ttft_deadline=ttft_deadline, deadline=deadline)
        try:
            self.scheduler.push(req)
        except EngineOverloaded:
            self.counters["rejected"] += 1
            raise
        self._next_rid += 1
        self.statuses[req.rid] = RequestStatus.QUEUED
        return req.rid

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + in a slot)."""
        return len(self.scheduler) + sum(r is not None for r in self._rid)

    def status(self, rid: int) -> Optional[RequestStatus]:
        """Last known status of a request (None for unknown rids)."""
        return self.statuses.get(rid)

    def stats(self) -> Dict[str, int]:
        """Host-side health counters: terminal-outcome totals plus the
        instantaneous queue / slot occupancy the load generator and CLI
        report."""
        return {
            **self.counters,
            "queue_depth": len(self.scheduler),
            "queued_tokens": self.scheduler.queued_tokens,
            "slots_occupied": sum(r is not None for r in self._rid),
            "slots_total": self.slots.max_slots,
            "ticks": self.tick_count,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
        }

    def snapshot(self) -> Dict[str, Any]:
        """Postmortem view of the engine (attached to `EngineStalled`)."""
        return {
            "tick": self.tick_count,
            "queue_depth": len(self.scheduler),
            "queued_tokens": self.scheduler.queued_tokens,
            "slots": [
                {"slot": i, "rid": self._rid[i],
                 "position": int(self.slots.position[i]),
                 "prompt_len": int(self._prompt_len[i]),
                 "active": bool(self.slots.active[i]),
                 "eos": bool(self.slots.eos[i])}
                for i in range(self.slots.max_slots)],
            "counters": dict(self.counters),
            "tick_time": self.monitor.stats(),
        }

    # -- cancellation --------------------------------------------------------

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is — queued, mid-prefill, or
        mid-decode. Frees its slot immediately, drops its prefix-cache
        snapshots, and records a CANCELLED `FinishedRequest` (with the
        tokens generated so far) in `history`. Returns False for unknown
        or already-terminal rids."""
        req = self.scheduler.remove(rid)
        if req is not None:
            self._finalize(req, [], RequestStatus.CANCELLED,
                           "cancelled while queued", [])
            return True
        for slot in range(self.slots.max_slots):
            if self._rid[slot] == rid:
                req = self._req[rid]
                phase = "decode" if self.slots.active[slot] else "prefill"
                toks = self._generated.pop(rid, [])
                if self.prefix_cache is not None:
                    self.prefix_cache.invalidate(req.prompt)
                self._rid[slot] = None
                del self._req[rid]
                self.slots.evict(slot)
                self._finalize(req, toks, RequestStatus.CANCELLED,
                               f"cancelled mid-{phase}", [])
                return True
        return False

    # -- the tick ------------------------------------------------------------

    def step(self) -> List[FinishedRequest]:
        """Advance the pool by one mixed prefill+decode launch. Returns
        every request that reached a terminal state this tick (finished,
        failed, timed out, or shed)."""
        self.monitor.start_step()
        self.tick_count += 1
        if self.faults is not None:
            self.faults.apply(self, self.tick_count)
        finished: List[FinishedRequest] = []
        self._expire_deadlines(finished)
        self._shed_if_saturated(finished)
        admitted = self._admit()

        pre = self._pick_prefill()
        live = self.slots.active & ~self.slots.eos
        do_decode = bool(live.any())
        if pre is not None or do_decode:
            slot = chunk_tok = kv_mask = off = nvalid = None
            if pre is not None:
                slot, chunk_tok, kv_mask, off, nvalid = pre
            state, first_tok, pre_ok, nxt, dec_ok = self._tick_fn(
                self.params, self.slots.state,
                None if pre is None else jnp.asarray(slot, jnp.int32),
                chunk_tok, kv_mask,
                None if pre is None else jnp.asarray(off, jnp.int32),
                None if pre is None else jnp.asarray(nvalid, jnp.int32),
                None if not do_decode else jnp.asarray(self._last_token),
                None if not do_decode else jnp.asarray(self.slots.position),
                None if not do_decode else jnp.asarray(live),
                do_prefill=pre is not None, do_decode=do_decode)
            self.slots.state = state

            if pre is not None:
                self._after_prefill(slot, nvalid, first_tok,
                                    bool(np.asarray(pre_ok)), finished)
            if do_decode:
                self._after_decode(live, np.asarray(nxt),
                                   np.asarray(dec_ok), finished)
            if self._check_state:
                self._deep_state_check(finished)

        progressed = bool(admitted or pre is not None or do_decode
                          or finished)
        self._watchdog(self.monitor.end_step(), progressed)
        return finished

    def run(self, *, max_ticks: int = 1_000_000) -> Dict[int, np.ndarray]:
        """Drive ticks until every submitted request reached a terminal
        state. Returns {rid: tokens} for every request that terminated
        inside the loop (failed/timed-out entries carry the tokens
        generated before the fault). Raises `EngineStalled` — with an
        engine snapshot — if `max_ticks` is exhausted with requests still
        pending, instead of silently returning a partial map."""
        done: Dict[int, np.ndarray] = {}
        for _ in range(max_ticks):
            if not self.pending:
                return done
            for fin in self.step():
                done[fin.rid] = fin.tokens
        if self.pending:
            raise EngineStalled(
                f"run() exhausted max_ticks={max_ticks} with {self.pending} "
                f"requests still pending "
                f"({len(self.scheduler)} of them queued)", self.snapshot())
        return done

    def stream(self, prompt, max_new_tokens: int, *,
               eos_id=None) -> Iterator[int]:
        """Submit one request and yield its tokens as they are produced
        (other already-submitted requests keep making progress). Stops
        cleanly if the request reaches ANY terminal state — a cancelled or
        failed stream simply ends after its last good token."""
        box: List[int] = []
        rid = self.submit(prompt, max_new_tokens, eos_id=eos_id,
                          callback=lambda _rid, tok: box.append(tok))
        while True:
            fins = self.step()
            while box:
                yield box.pop(0)
            if any(f.rid == rid for f in fins):
                return
            if self.statuses.get(rid) in TERMINAL_STATUSES:
                return              # cancelled/failed outside this tick

    # -- internals -----------------------------------------------------------

    def _finalize(self, req: Request, tokens, status: RequestStatus,
                  error: Optional[str],
                  finished: List[FinishedRequest]) -> FinishedRequest:
        """Single exit point for every terminal outcome: stamp the request,
        bump the status counter, and record the FinishedRequest."""
        req.finish_time = time.monotonic()
        req.status = status
        req.error = error
        fin = FinishedRequest(
            rid=req.rid,
            tokens=np.asarray(tokens, np.int32),
            prompt_len=len(req.prompt),
            ttft=(None if req.first_token_time is None
                  else req.first_token_time - req.submit_time),
            latency=req.finish_time - req.submit_time,
            status=status, error=error)
        self.statuses[req.rid] = status
        self.counters[_TERMINAL_COUNTER[status]] += 1
        self.history.append(fin)
        finished.append(fin)
        return fin

    def _expire_deadlines(self, finished: List[FinishedRequest]) -> None:
        now = time.monotonic()
        for req in self.scheduler.take_expired(now):
            self._finalize(
                req, [], RequestStatus.TIMED_OUT,
                f"RequestTimeout: deadline expired after "
                f"{now - req.submit_time:.3f}s in queue", finished)
        for slot in range(self.slots.max_slots):
            rid = self._rid[slot]
            if rid is None:
                continue
            req = self._req[rid]
            waited = now - req.submit_time
            if req.first_token_time is None and \
                    req.ttft_deadline is not None and \
                    waited > req.ttft_deadline:
                self._release_abnormal(
                    slot, RequestStatus.TIMED_OUT,
                    f"RequestTimeout: TTFT deadline {req.ttft_deadline}s "
                    f"expired after {waited:.3f}s (prefill at "
                    f"{int(self.slots.position[slot])}/"
                    f"{int(self._prompt_len[slot])})", finished)
            elif req.deadline is not None and waited > req.deadline:
                self._release_abnormal(
                    slot, RequestStatus.TIMED_OUT,
                    f"RequestTimeout: deadline {req.deadline}s expired "
                    f"after {waited:.3f}s", finished)

    def _shed_if_saturated(self, finished: List[FinishedRequest]) -> None:
        """Graceful degradation: once the bounded queue has been FULL for
        `shed_after` consecutive ticks, shed the newest/largest waiters
        down to 3/4 depth — predictable victims with a clear status instead
        of unbounded waiting for everyone."""
        depth_cap = self.scheduler.max_depth
        if not self.shed_after or not depth_cap:
            return
        if len(self.scheduler) >= depth_cap:
            self._saturated_ticks += 1
        else:
            self._saturated_ticks = 0
            return
        if self._saturated_ticks < self.shed_after:
            return
        target = max(1, (3 * depth_cap) // 4)
        while len(self.scheduler) > target:
            req = self.scheduler.shed()
            if req is None:
                break
            self._finalize(
                req, [], RequestStatus.REJECTED,
                f"shed after {self._saturated_ticks} ticks of sustained "
                f"queue saturation (depth {depth_cap})", finished)
        self._saturated_ticks = 0            # re-arm

    def _watchdog(self, dt: float, progressed: bool) -> None:
        """Stall detection: sustained blown tick budgets or sustained
        no-progress ticks (with requests pending) raise `EngineStalled`
        carrying `snapshot()` — the engine never silently spins."""
        if self.tick_budget_s is not None and dt > self.tick_budget_s:
            self._budget_strikes += 1
            if self._budget_strikes >= self._budget_patience:
                raise EngineStalled(
                    f"tick wall-clock budget blown "
                    f"{self._budget_strikes}x in a row (last tick "
                    f"{dt * 1e3:.1f}ms > budget "
                    f"{self.tick_budget_s * 1e3:.1f}ms)", self.snapshot())
        else:
            self._budget_strikes = 0
        if self.pending and not progressed:
            self._stall_strikes += 1
            if self._stall_strikes >= self.stall_ticks:
                raise EngineStalled(
                    f"no tick progress for {self._stall_strikes} ticks "
                    f"with {self.pending} requests pending", self.snapshot())
        else:
            self._stall_strikes = 0

    def _admit(self) -> int:
        n = 0
        for slot in range(self.slots.max_slots):
            if self._rid[slot] is not None:
                continue
            req = self.scheduler.pop(self.tick_count)
            if req is None:
                return n
            offset, snap = (0, None)
            if self.prefix_cache is not None:
                offset, snap = self.prefix_cache.lookup(req.prompt)
            self.slots.admit(slot, unit_state=snap, position=offset)
            self._rid[slot] = req.rid
            self._req[req.rid] = req
            self._prompt_len[slot] = len(req.prompt)
            self._generated[req.rid] = []
            req.status = RequestStatus.PREFILL
            self.statuses[req.rid] = RequestStatus.PREFILL
            self.counters["admitted"] += 1
            n += 1
        return n

    def _pick_prefill(self):
        """Next slot still owing prompt tokens -> its next chunk.

        Round-robin from a persistent cursor, NOT always the lowest slot:
        one tick prefills one chunk, so a lowest-first scan would feed
        slot 0's long prompt to completion while later slots (admitted the
        same tick) wait at position 0 — head-of-line bias that inflates
        their TTFT. The cursor resumes after the last-served slot so
        concurrent prompts interleave chunk-for-chunk."""
        b = self.slots.max_slots
        for i in range(b):
            slot = (self._prefill_cursor + i) % b
            rid = self._rid[slot]
            if rid is None or self.slots.active[slot] or self.slots.eos[slot]:
                continue
            pos = int(self.slots.position[slot])
            plen = int(self._prompt_len[slot])
            if pos >= plen:
                continue
            self._prefill_cursor = (slot + 1) % b
            n = min(self.chunk, plen - pos)
            toks = self._req[rid].prompt[pos:pos + n]
            if n == self.chunk:
                chunk_tok = jnp.asarray(toks[None], jnp.int32)
                kv_mask = None
            elif self._pad_ragged:
                padded = np.zeros(self.chunk, np.int32)
                padded[:n] = toks
                chunk_tok = jnp.asarray(padded[None], jnp.int32)
                kv_mask = jnp.asarray(
                    (np.arange(self.chunk) < n)[None].astype(np.float32))
            else:
                chunk_tok = jnp.asarray(toks[None], jnp.int32)
                kv_mask = None
            return slot, chunk_tok, kv_mask, pos, n

    def _after_prefill(self, slot: int, nvalid: int, first_tok, ok: bool,
                       finished: List[FinishedRequest]) -> None:
        if not ok:
            self._quarantine_slot(
                slot, "SlotQuarantined: non-finite logits in prefill chunk "
                      f"(position {int(self.slots.position[slot])})",
                finished)
            return
        rid = self._rid[slot]
        req = self._req[rid]
        self.slots.position[slot] += nvalid
        self.prefill_tokens += int(nvalid)
        pos = int(self.slots.position[slot])
        plen = int(self._prompt_len[slot])
        if self.prefix_cache is not None and pos % self.chunk == 0:
            self.prefix_cache.insert(req.prompt, pos,
                                     self.slots.snapshot(slot))
        if pos < plen:
            return
        # prompt complete: the prefill logits' last valid row is token #1
        tok = int(np.asarray(first_tok)[0])
        self.slots.active[slot] = True
        self._last_token[slot] = tok
        if req.first_token_time is None:
            req.first_token_time = time.monotonic()
        req.status = RequestStatus.DECODE
        self.statuses[rid] = RequestStatus.DECODE
        self._emit(slot, rid, tok, finished)

    def _after_decode(self, live: np.ndarray, nxt: np.ndarray,
                      ok: np.ndarray,
                      finished: List[FinishedRequest]) -> None:
        for slot in np.nonzero(live)[0]:
            slot = int(slot)
            rid = self._rid[slot]
            if rid is None:
                continue            # freed earlier this tick
            if not ok[slot]:
                self._quarantine_slot(
                    slot, "SlotQuarantined: non-finite logits in decode "
                          f"step (position {int(self.slots.position[slot])})",
                    finished)
                continue
            tok = int(nxt[slot])
            self.slots.position[slot] += 1
            self._last_token[slot] = tok
            self.decode_tokens += 1
            self._emit(slot, rid, tok, finished)

    def _quarantine_slot(self, slot: int, error: str,
                         finished: List[FinishedRequest]) -> None:
        """Fail ONLY the poisoned request: drop its prefix-cache snapshots
        (they may carry the same non-finite state), re-initialize the slot
        from the fresh template, and keep every other slot serving."""
        rid = self._rid[slot]
        req = self._req[rid]
        toks = self._generated.pop(rid, [])
        if self.prefix_cache is not None:
            self.prefix_cache.invalidate(req.prompt)
        self._rid[slot] = None
        del self._req[rid]
        self.slots.quarantine(slot)
        self.counters["quarantined"] += 1
        self._finalize(req, toks, RequestStatus.FAILED, error, finished)

    def _release_abnormal(self, slot: int, status: RequestStatus,
                          error: str,
                          finished: List[FinishedRequest]) -> None:
        """Free a slot whose request terminated abnormally (deadline).
        Plain evict — the state is finite, just no longer wanted."""
        rid = self._rid[slot]
        req = self._req[rid]
        toks = self._generated.pop(rid, [])
        self._rid[slot] = None
        del self._req[rid]
        self.slots.evict(slot)
        self._finalize(req, toks, status, error, finished)

    def _deep_state_check(self, finished: List[FinishedRequest]) -> None:
        """REPRO_SERVE_CHECK_STATE=1: one jitted reduction over every
        floating decode-state leaf per tick -> per-slot finite flags.
        Catches moment-lane overflow BEFORE it surfaces in logits (and
        before a poisoned snapshot can enter the prefix cache)."""
        if self._finite_fn is None:
            self._finite_fn = jax.jit(functools.partial(
                _finite_per_slot, axes=self.slots.axes,
                n=self.slots.max_slots))
        ok = np.asarray(self._finite_fn(self.slots.state))
        for slot in np.nonzero(~ok)[0]:
            slot = int(slot)
            if self._rid[slot] is None:
                # free slot holding stale non-finite leaves: scrub quietly
                self.slots.quarantine(slot)
                continue
            self._quarantine_slot(
                slot, "SlotQuarantined: non-finite decode-state leaf "
                      "(REPRO_SERVE_CHECK_STATE deep check)", finished)

    def _emit(self, slot: int, rid: int, tok: int,
              finished: List[FinishedRequest]) -> None:
        req = self._req[rid]
        self._generated[rid].append(tok)
        if req.callback is not None:
            try:
                req.callback(rid, tok)
            except Exception as e:  # noqa: BLE001 — user code must not
                # kill the pool: fail only this request, keep serving
                toks = self._generated.pop(rid, [])
                self._rid[slot] = None
                del self._req[rid]
                self.slots.evict(slot)
                self._finalize(
                    req, toks, RequestStatus.FAILED,
                    f"on_token callback raised: {e!r}", finished)
                return
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(self._generated[rid]) >= req.max_new_tokens:
            toks = self._generated.pop(rid)
            self._rid[slot] = None
            del self._req[rid]
            self.slots.evict(slot)
            self._finalize(req, toks, RequestStatus.FINISHED, None, finished)


def _tick(params, state, slot, chunk_tok, kv_mask, off, nvalid,
          tokens, positions, live, *, cfg, axes,
          do_prefill: bool, do_decode: bool):
    """One mixed launch: chunked prefill for one slot + a batched decode
    step for the live slots, on the shared pool state. Static
    do_prefill/do_decode flags -> at most 3 traces. Alongside the emitted
    tokens, each part returns a finite-logits flag (scalar for the prefill
    chunk, per-slot [B] for decode) — the cheap non-finite guard the
    quarantine path keys on."""
    first_tok = pre_ok = None
    if do_prefill:
        unit = read_slot(state, slot, axes)
        logits, unit = lm_prefill(params, chunk_tok, cfg, unit,
                                  offset=off, kv_mask=kv_mask)
        last_row = jax.lax.dynamic_index_in_dim(logits, nvalid - 1, axis=1,
                                                keepdims=False)
        first_tok = jnp.argmax(last_row, axis=-1).astype(jnp.int32)
        pre_ok = jnp.isfinite(last_row).all()
        state = write_slot(state, unit, slot, axes)
    nxt = dec_ok = None
    if do_decode:
        logits, new_state = lm_decode_step(params, state, tokens, cfg,
                                           position=positions)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        dec_ok = jnp.isfinite(logits).all(axis=-1)
        state = select_slots(live, new_state, state, axes)
        nxt = jnp.where(live, nxt, tokens)
    return state, first_tok, pre_ok, nxt, dec_ok


def _finite_per_slot(state, *, axes, n):
    """[n] bool: slot i's floating leaves are all finite. Integer lanes
    (cursors, token ids) are skipped — they cannot hold NaN/Inf."""
    ok = jnp.ones((n,), bool)
    for leaf, ax in zip(jax.tree.leaves(state), jax.tree.leaves(axes)):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        flat = jnp.moveaxis(leaf, ax, 0).reshape(n, -1)
        ok = ok & jnp.isfinite(flat).all(axis=1)
    return ok
