"""Prompt-prefix cache: decode-state snapshots keyed by token-prefix hash.

Because prefill is resumable (``lm_prefill(offset=...)`` seeds the fastmax
moment scan / writes KV rows at an offset), a snapshot of a slot's state
after ``m`` prompt tokens lets any later request whose prompt starts with
the same ``m`` tokens skip straight to ``offset=m``. Snapshots are taken at
chunk boundaries during prefill, so keys are always prefixes of length
``k * chunk``.

For fastmax backends a snapshot is the constant-size moment tuple, so a
generous byte budget holds MANY prefixes; for the softmax baseline each
snapshot carries full ``max_len`` KV rows — the same O(1)-vs-O(N)
asymmetry the engine's slot accounting reports.

Entries are LRU-evicted once the byte budget is exceeded. All state stays
on device; the cache only holds references + host metadata.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["PrefixCache", "prefix_key"]


def prefix_key(prompt: np.ndarray, m: int) -> str:
    """Stable key for the first `m` tokens of `prompt`."""
    pre = np.ascontiguousarray(np.asarray(prompt[:m], np.int32))
    return hashlib.sha1(pre.tobytes()).hexdigest()


def _state_bytes(state: Any) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(state)))


class PrefixCache:
    def __init__(self, byte_budget: int, *, chunk: int):
        self.byte_budget = int(byte_budget)
        self.chunk = int(chunk)
        self._entries: "OrderedDict[str, Tuple[int, Any, int]]" = \
            OrderedDict()  # key -> (m, state, nbytes)
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, prompt: np.ndarray) -> Tuple[int, Optional[Any]]:
        """Longest cached prefix of `prompt` STRICTLY shorter than the
        prompt (at least one token must go through prefill to produce the
        first logits). Returns (m, state) or (0, None)."""
        plen = len(prompt)
        top = (plen - 1) // self.chunk * self.chunk
        if top <= 0:
            # no cacheable prefix even exists at this length (keys are
            # multiples of chunk, strictly shorter than the prompt) — not a
            # miss, or sub-chunk prompts would skew the hit-rate stats
            return 0, None
        for m in range(top, 0, -self.chunk):
            key = prefix_key(prompt, m)
            ent = self._entries.get(key)
            if ent is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return ent[0], ent[1]
        self.misses += 1
        return 0, None

    def insert(self, prompt: np.ndarray, m: int, state: Any) -> None:
        """Cache `state` as the snapshot after the first `m` tokens of
        `prompt` (m must sit on a chunk boundary)."""
        if self.byte_budget <= 0 or m <= 0 or m % self.chunk:
            return
        key = prefix_key(prompt, m)
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        nbytes = _state_bytes(state)
        if nbytes > self.byte_budget:
            return
        self._entries[key] = (m, state, nbytes)
        self.bytes += nbytes
        self.insertions += 1
        while self.bytes > self.byte_budget:
            _, (_, _, nb) = self._entries.popitem(last=False)
            self.bytes -= nb
            self.evictions += 1

    def invalidate(self, prompt: np.ndarray) -> int:
        """Drop every cached snapshot keyed by a chunk-boundary prefix of
        `prompt`. Used when the request that produced the snapshots is
        cancelled (its device references should be released) or its slot is
        quarantined (snapshots taken from a poisoned slot must never seed
        another request). Returns the number of entries removed."""
        removed = 0
        for m in range(self.chunk, len(prompt) + 1, self.chunk):
            ent = self._entries.pop(prefix_key(prompt, m), None)
            if ent is not None:
                self.bytes -= ent[2]
                self.evictions += 1
                removed += 1
        return removed

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.bytes,
                "hits": self.hits, "misses": self.misses,
                "insertions": self.insertions, "evictions": self.evictions}
