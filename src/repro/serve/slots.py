"""Slot-indexed batched decode state: a fixed pool of B sequence slots.

The continuous-batching engine keeps ONE model decode state allocated for
`max_slots` sequences and treats its batch dimension as a pool of slots.
Admitting or evicting a request is a write of one slot's leaves — a
`dynamic_update_slice` per leaf, O(1) in pool size and fully jitted, so the
engine never retraces as requests come and go.

What makes this work for every backend family:

  fastmax  -> a slot's state is the constant-size moment tuple
              (O(D^2 Dv) per kv head, independent of context length) — a
              500k-context slot costs the same bytes as a 64-token one.
              Continuous batching needs NONE of the paged-KV block-table
              machinery softmax serving requires.
  softmax  -> a slot's state is `max_len` masked KV-cache rows with a
              per-slot write cursor (`KVCache.length` as a [B] lane) — the
              O(N) baseline the benchmark compares against.

Because a model decode state is an arbitrary pytree (stacked layer groups
put the slot axis at position 1; `KVCache.length` lanes have it last; SSM
states lead with it), the slot axis of every leaf is discovered ONCE per
(config, pool) by comparing `jax.eval_shape` trees at two different batch
sizes — the one axis whose extent changes with batch is the slot axis.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.attention.state import KVCache

__all__ = ["SlotPool", "SlotManager", "to_slotted", "slot_batch_axes",
           "write_slot", "read_slot", "select_slots"]


def to_slotted(state: Any):
    """Give every `KVCache` in a freshly-initialized decode state a
    PER-SLOT write cursor: `length` [] -> [B] (or [n_groups] ->
    [n_groups, B] under the stacked layer groups), so slots can sit at
    different context lengths inside one batched step."""
    def fix(node):
        if isinstance(node, KVCache):
            b = node.k.shape[node.length.ndim]
            return node._replace(
                length=jnp.zeros(node.length.shape + (b,), jnp.int32))
        return node

    return jax.tree.map(fix, state,
                        is_leaf=lambda x: isinstance(x, KVCache))


def slot_batch_axes(make_state):
    """Per-leaf slot-axis pytree for states built by `make_state(batch)`.

    Compares abstract shapes at batch 2 vs 3: exactly one axis must differ
    per leaf (the slot axis). A leaf whose shape does not depend on batch
    would be shared across slots — that is a bug (it cannot be admitted or
    evicted per-request), so it raises.
    """
    s2 = jax.eval_shape(lambda: make_state(2))
    s3 = jax.eval_shape(lambda: make_state(3))

    def one_axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"decode-state leaf {a.shape} has no unique slot axis "
                f"(vs {b.shape}) — a shared leaf cannot be slot-pooled")
        return diffs[0]

    return jax.tree.map(one_axis, s2, s3)


def write_slot(pool_state, unit_state, slot, axes):
    """Write a batch-1 unit state into slot `slot` (traced index): one
    dynamic_update_slice per leaf — O(1) admit/evict, no retrace."""
    def w(p, u, ax):
        return jax.lax.dynamic_update_slice_in_dim(
            p, u.astype(p.dtype), slot, axis=ax)

    return jax.tree.map(w, pool_state, unit_state, axes)


def read_slot(pool_state, slot, axes):
    """Gather slot `slot` as a batch-1 unit state (prefix-cache snapshots,
    chunked-prefill gather)."""
    def r(p, ax):
        return jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=ax)

    return jax.tree.map(r, pool_state, axes)


def select_slots(keep, new_state, old_state, axes):
    """Per-slot select: keep[i] ? new : old for every leaf. Used by the
    engine's decode tick so inactive / mid-prefill slots are untouched by
    the batched step that ran over them."""
    def sel(n, o, ax):
        shape = [1] * n.ndim
        shape[ax] = keep.shape[0]
        return jnp.where(keep.reshape(shape), n, o)

    return jax.tree.map(sel, new_state, old_state, axes)


class SlotPool(NamedTuple):
    """Device-side pool + host-side per-slot lanes (numpy mirrors)."""
    state: Any             # model decode state, slot axis per `axes`
    position: Any          # np [B] int32: committed tokens (next position)
    active: Any            # np [B] bool: decoding (prefill done, not eos)
    eos: Any               # np [B] bool: finished (eos / budget), evictable


class SlotManager:
    """Owns the pooled decode state and the per-slot lanes.

    Device state stays on device between ticks; the tiny int/bool lanes
    live host-side (numpy) because the engine reads and branches on them
    every tick anyway (admission, eviction, streaming).
    """

    def __init__(self, cfg, max_slots: int, max_len: int):
        import numpy as np

        from repro.models import init_decode_state

        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self._make = lambda b: to_slotted(init_decode_state(cfg, b, max_len))
        self.axes = slot_batch_axes(self._make)
        self.state = self._make(max_slots)
        # fresh unit state template, reused for every cold admit (slstm's
        # `m` lane inits to -1e9 — zeros_like would be wrong)
        self.fresh_unit = self._make(1)
        self.position = np.zeros(max_slots, np.int32)
        self.active = np.zeros(max_slots, bool)
        self.eos = np.zeros(max_slots, bool)
        self._write = jax.jit(
            functools.partial(write_slot, axes=self.axes))
        self._read = jax.jit(
            functools.partial(read_slot, axes=self.axes))

    # -- O(1) admit / evict --------------------------------------------------

    def admit(self, slot: int, unit_state=None, position: int = 0):
        """Install a unit state (fresh, or a prefix-cache snapshot covering
        `position` tokens) into `slot`."""
        unit = self.fresh_unit if unit_state is None else unit_state
        self.state = self._write(self.state, unit,
                                 jnp.asarray(slot, jnp.int32))
        self.position[slot] = position
        self.active[slot] = False
        self.eos[slot] = False

    def evict(self, slot: int):
        """Free a slot. The state is NOT cleared — the next admit fully
        overwrites every leaf of the slot, so eviction is pure
        host bookkeeping."""
        self.active[slot] = False
        self.eos[slot] = False
        self.position[slot] = 0

    def quarantine(self, slot: int):
        """Free a slot AND re-initialize its device state from the fresh
        template. Unlike `evict`, the state write matters here: a poisoned
        slot (NaN/Inf leaves) must not sit in the pool where a deep state
        check (`REPRO_SERVE_CHECK_STATE=1`) or a leaky select would see it.
        The slot is immediately reusable."""
        self.state = self._write(self.state, self.fresh_unit,
                                 jnp.asarray(slot, jnp.int32))
        self.evict(slot)

    def snapshot(self, slot: int):
        """Batch-1 copy of a slot's state (prefix cache entries)."""
        return self._read(self.state, jnp.asarray(slot, jnp.int32))

    def state_bytes_per_slot(self) -> int:
        """Slot cost in bytes — constant in context for fastmax, linear for
        the softmax KV baseline (see core.decode_state.decode_state_bytes)."""
        from repro.core.decode_state import decode_state_bytes
        return decode_state_bytes(self.cfg, 1, self.max_len)
