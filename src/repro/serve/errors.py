"""Request lifecycle + structured error taxonomy for `repro.serve`.

A request moves through a small state machine; every terminal state is
reported as a `FinishedRequest` carrying the status and a diagnostic, so
callers never have to infer "what happened" from a missing rid:

    QUEUED ──admit──► PREFILL ──first token──► DECODE ──eos/budget──► FINISHED
      │                  │                        │
      │                  └───── callback raise / non-finite ────────► FAILED
      ├─ cancel() ───────┴──────────────────────────────────────────► CANCELLED
      ├─ deadline ──────────────────────────────────────────────────► TIMED_OUT
      └─ load shed ─────────────────────────────────────────────────► REJECTED

`submit()` raising `EngineOverloaded` is the one outcome with no
`FinishedRequest`: the request was never accepted, so no rid exists.

The exceptions partition the failure modes the engine distinguishes:

    EngineOverloaded   admission refused (queue depth / prompt-token budget)
    RequestTimeout     a per-request TTFT or total deadline expired (used as
                       the diagnostic on TIMED_OUT finishes; raised only if
                       a caller opts into exceptions via `strict` helpers)
    SlotQuarantined    non-finite values reached a slot's emissions; the
                       slot was re-initialized and only that request failed
    EngineStalled      the watchdog tripped: no tick progress / tick
                       wall-clock budget blown / `run()` exhausted
                       `max_ticks` with requests still pending — carries an
                       engine snapshot for postmortems
"""
from __future__ import annotations

import enum
from typing import Any, Optional

__all__ = ["RequestStatus", "TERMINAL_STATUSES", "ServeError",
           "EngineOverloaded", "RequestTimeout", "SlotQuarantined",
           "EngineStalled"]


class RequestStatus(str, enum.Enum):
    QUEUED = "queued"          # accepted, waiting for a slot
    PREFILL = "prefill"        # in a slot, prompt chunks still running
    DECODE = "decode"          # first token emitted, decoding
    FINISHED = "finished"      # eos or max_new_tokens reached
    FAILED = "failed"          # callback raised / non-finite quarantine
    CANCELLED = "cancelled"    # cancel(rid)
    TIMED_OUT = "timed_out"    # TTFT or total deadline expired
    REJECTED = "rejected"      # shed from the queue under sustained overload

    def __str__(self) -> str:  # stable in messages / JSON
        return self.value


TERMINAL_STATUSES = frozenset({
    RequestStatus.FINISHED, RequestStatus.FAILED, RequestStatus.CANCELLED,
    RequestStatus.TIMED_OUT, RequestStatus.REJECTED})


class ServeError(RuntimeError):
    """Base class for structured serving failures."""


class EngineOverloaded(ServeError):
    """`submit()` refused: the bounded queue (depth or prompt-token budget)
    is full. Callers should back off / retry elsewhere; the engine state is
    unchanged."""


class RequestTimeout(ServeError):
    """A per-request deadline (TTFT or total latency) expired. The request
    finished with status TIMED_OUT; this class names the diagnostic."""


class SlotQuarantined(ServeError):
    """Non-finite values (NaN/Inf) reached a slot's logits or — with
    REPRO_SERVE_CHECK_STATE=1 — its decode-state leaves. The slot was
    re-initialized from the fresh template and returned to the pool; only
    the poisoned request failed."""


class EngineStalled(ServeError):
    """The engine watchdog tripped. Carries `snapshot`, a host-side dict of
    engine state at the stall (tick, queue, per-slot lanes, counters, tick
    timing stats) for postmortems."""

    def __init__(self, message: str, snapshot: Optional[Any] = None):
        super().__init__(message)
        self.snapshot = snapshot

    def __str__(self) -> str:
        base = super().__str__()
        if not self.snapshot:
            return base
        snap = self.snapshot
        slots = snap.get("slots", [])
        busy = sum(1 for s in slots if s.get("rid") is not None)
        return (f"{base} [tick {snap.get('tick')}, queue "
                f"{snap.get('queue_depth')}, slots {busy}/{len(slots)} busy]")
