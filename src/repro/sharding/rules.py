"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter carries a tuple of logical axis names (repro.models.param);
`spec_for` maps them to a PartitionSpec against the active mesh with
divisibility fallback: if a mesh-axis product does not divide the dim (e.g.
kv_heads=8 on model=16, or kv=1 MQA), the dim falls back to fewer axes or
replication — never an invalid spec.

Parallelism map (single pod (data=16, model=16); multi-pod adds "pod"):
  DP    batch            -> ("pod", "data")
  FSDP  weights' embed    -> "data"  (ZeRO-3 within pod; pods replicate,
                                      optimizer state can add "pod")
  TP    heads/ff/vocab    -> "model"
  EP    experts           -> "model"
  SP    long-context decode state feature dims -> ("data","model") when the
        batch can't use "data" (batch=1 long_500k)
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "spec_for", "param_shardings", "batch_spec",
           "decode_state_shardings", "maybe_constraint", "replicate",
           "active_mesh"]


def active_mesh():
    """The mesh sharding constraints should target, or None.

    One place for the JAX-version-sensitive discovery dance:
    `get_abstract_mesh` where it exists (newer JAX), falling back to the
    legacy `with mesh:` thread-resources env (0.4.x — where the abstract-
    mesh accessor is absent and the naive call raises; a stale copy of
    this fallback once left `feature_shard_flag` returning False on every
    call, so keep the logic HERE only)."""
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        try:
            from jax._src import mesh as mesh_lib
            mesh = mesh_lib.thread_resources.env.physical_mesh
        except Exception:
            return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def replicate(x, *, batch_dim=None):
    """with_sharding_constraint to model-replicated; no-op without an active
    mesh. Used to pin small tensors (queries/denominators on the serve
    combine path) so XLA doesn't propagate a large-tensor sharding conflict
    through them. `batch_dim` keeps data parallelism on that dim (greedy
    pod/data axes when they divide it) while every other dim is pinned
    replicated."""
    mesh = active_mesh()
    if mesh is None:
        return x
    entries = [None] * x.ndim
    if batch_dim is not None:
        chosen = []
        prod = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names and x.shape[batch_dim] > 1 \
                    and x.shape[batch_dim] % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        if chosen:
            entries[batch_dim] = (chosen[0] if len(chosen) == 1
                                  else tuple(chosen))
    return jax.lax.with_sharding_constraint(x, P(*entries))


def maybe_constraint(x, *want_axes):
    """with_sharding_constraint that degrades gracefully: applies only the
    axes present in the active mesh AND dividing the dim; no-op without a
    mesh (smoke tests on 1 device)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    used: set = set()
    entries = []
    for dim, cand in enumerate(want_axes):
        if cand is None:
            entries.append(None)
            continue
        cands = cand if isinstance(cand, tuple) else (cand,)
        chosen = []
        prod = 1
        for a in cands:
            if a in mesh.axis_names and a not in used \
                    and x.shape[dim] % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        used.update(chosen)
        entries.append(None if not chosen
                       else (chosen[0] if len(chosen) == 1
                             else tuple(chosen)))
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))

DEFAULT_RULES = {
    "embed": ("data",),       # FSDP/ZeRO-3 for weight matrices
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
}

NO_FSDP_RULES = {**DEFAULT_RULES, "embed": ()}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for(axes: tuple, shape: tuple, mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for logical, size in zip(axes, shape):
        if logical is None:
            out.append(None)
            continue
        want = [a for a in rules.get(logical, ()) if a not in used
                and a in mesh.axis_names]
        # greedy prefix that divides the dim size
        chosen = []
        prod = 1
        for a in want:
            if size % (prod * _axis_size(mesh, a)) == 0:
                chosen.append(a)
                prod *= _axis_size(mesh, a)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
            used.add(chosen[0])
        else:
            out.append(tuple(chosen))
            used.update(chosen)
    return P(*out)


def param_shardings(axes_tree, shape_tree, mesh: Mesh,
                    rules: Optional[dict] = None):
    """Tree of NamedSharding matching a param (or same-shaped state) tree."""
    is_axes_leaf = lambda x: isinstance(x, tuple)  # noqa: E731

    def one(axes, leaf):
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, rules))

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes_leaf)


def batch_spec(mesh: Mesh, *, batch_size: int) -> P:
    """Batch-dim sharding: as much DP as divides the global batch."""
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and batch_size % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def _dim_spec(size: int, mesh: Mesh, prefer: list, used: set):
    """Greedy: shard `size` over the first unused axes that divide it."""
    chosen = []
    prod = 1
    for a in prefer:
        if a in mesh.axis_names and a not in used \
                and size % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    for a in chosen:
        used.add(a)
    if not chosen:
        return None
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


def decode_state_shardings(state_shapes, mesh: Mesh, *, batch: int):
    """Shard a decode-state tree (KV caches / fastmax moments / ssm states).

    Strategy per leaf [B, ...rest]: batch -> (pod, data) when divisible;
    then the LARGEST remaining dims -> remaining mesh axes (model first).
    This realizes: moment-feature TP for fastmax (D or D^2 over "model"),
    sequence-sharded KV caches (N over "model"), and full feature sharding
    ("data"+"model") for batch=1 long-context decode.
    """
    def one(leaf):
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        used: set = set()
        out = []
        # dim 0 = batch
        b_axes = []
        prod = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names and shape[0] % (prod * mesh.shape[a]) == 0 \
                    and shape[0] > 1:
                b_axes.append(a)
                prod *= mesh.shape[a]
        for a in b_axes:
            used.add(a)
        out.append(None if not b_axes
                   else (b_axes[0] if len(b_axes) == 1 else tuple(b_axes)))
        # remaining dims: LAST dim first (fastmax moments combine locally
        # when the Dv dim is sharded; the m-dim gets sliced by the m-block
        # loop and must stay unsharded), then largest remaining
        order = sorted(range(1, len(shape)),
                       key=lambda i: (0 if i == len(shape) - 1 else 1,
                                      -shape[i]))
        specs = {i: None for i in order}
        for i in order:
            specs[i] = _dim_spec(shape[i], mesh,
                                 ["model", "data", "pod"], used)
        out.extend(specs[i] for i in range(1, len(shape)))
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(one, state_shapes)
