"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter carries a tuple of logical axis names (repro.models.param);
`spec_for` maps them to a PartitionSpec against the active mesh with
divisibility fallback: if a mesh-axis product does not divide the dim (e.g.
kv_heads=8 on model=16, or kv=1 MQA), the dim falls back to fewer axes or
replication — never an invalid spec.

Parallelism map (single pod (data=16, model=16); multi-pod adds "pod"):
  DP    batch            -> ("pod", "data")
  FSDP  weights' embed    -> "data"  (ZeRO-3 within pod; pods replicate,
                                      optimizer state can add "pod")
  TP    heads/ff/vocab    -> "model"
  EP    experts           -> "model"
  SP    long-context decode state feature dims -> ("data","model") when the
        batch can't use "data" (batch=1 long_500k)
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "spec_for", "param_shardings", "batch_spec",
           "decode_state_shardings", "maybe_constraint", "replicate",
           "active_mesh", "shard_stacked", "kv_cache_spec",
           "constrain_kv_cache", "model_axis_size"]


def active_mesh():
    """The mesh sharding constraints should target, or None.

    One place for the JAX-version-sensitive discovery dance:
    `get_abstract_mesh` where it exists (newer JAX), falling back to the
    legacy `with mesh:` thread-resources env (0.4.x — where the abstract-
    mesh accessor is absent and the naive call raises; a stale copy of
    this fallback once left `feature_shard_flag` returning False on every
    call, so keep the logic HERE only)."""
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        try:
            from jax._src import mesh as mesh_lib
            mesh = mesh_lib.thread_resources.env.physical_mesh
        except Exception:
            return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def replicate(x, *, batch_dim=None):
    """with_sharding_constraint to model-replicated; no-op without an active
    mesh. Used to pin small tensors (queries/denominators on the serve
    combine path) so XLA doesn't propagate a large-tensor sharding conflict
    through them. `batch_dim` keeps data parallelism on that dim (greedy
    pod/data axes when they divide it) while every other dim is pinned
    replicated."""
    mesh = active_mesh()
    if mesh is None:
        return x
    entries = [None] * x.ndim
    if batch_dim is not None:
        entries[batch_dim], _ = _batch_entry(mesh, x.shape[batch_dim])
    return jax.lax.with_sharding_constraint(x, P(*entries))


def model_axis_size(mesh=None) -> int:
    """Size of the 'model' (TP) axis of the given/active mesh; 1 if none."""
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    return mesh.shape["model"]


def shard_stacked(x, *, batch_dim=1, model_dim=None, seq_dim=None):
    """Pin a scan-stacked chunk tensor [nc, B, ...] to one total layout.

    The chunked-scan paths stack their per-chunk inputs/outputs along a
    leading axis and `lax.scan` over it. Once the scan CARRY is feature-TP
    constrained (`_constrain_moments_j`), the partitioner back-propagates
    'model' shardings into the stacked chunks and flip-flops against the
    batch layout they arrived with — the measured 0→12 involuntary-remat
    regression on train_4k (ROADMAP). Pinning each stacked tensor totally —
    DP axes on `batch_dim`, 'model' on `model_dim` (the value-feature dim of
    v/output chunks; None = model-replicated), everything else replicated —
    gives the scan one consistent layout at its boundary, so enabling
    feature-TP on the scan no longer induces remats.

    `seq_dim` pins the stacked-chunk axis itself to the "seq" (context-
    parallel) mesh axis when present and dividing: contiguous chunk runs
    then live on the device that owns those tokens, so a jnp chunked path
    under a CP mesh keeps its stacked buffers token-local instead of
    replicating nc full-size chunk tensors per device.

    No-op without an active mesh; axes that don't divide degrade to
    replication like every rule here.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    entries = [None] * x.ndim
    entries[batch_dim], _ = _batch_entry(mesh, x.shape[batch_dim])
    if model_dim is not None:
        model_dim = model_dim % x.ndim
        tp = model_axis_size(mesh)
        if tp > 1 and x.shape[model_dim] % tp == 0:
            entries[model_dim] = "model"
    if seq_dim is not None and "seq" in mesh.axis_names:
        seq_dim = seq_dim % x.ndim
        cp = mesh.shape["seq"]
        if cp > 1 and entries[seq_dim] is None \
                and x.shape[seq_dim] % cp == 0:
            entries[seq_dim] = "seq"
    return jax.lax.with_sharding_constraint(x, P(*entries))


def maybe_constraint(x, *want_axes):
    """with_sharding_constraint that degrades gracefully: applies only the
    axes present in the active mesh AND dividing the dim; no-op without a
    mesh (smoke tests on 1 device)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    used: set = set()
    entries = []
    for dim, cand in enumerate(want_axes):
        if cand is None:
            entries.append(None)
            continue
        cands = cand if isinstance(cand, tuple) else (cand,)
        chosen = []
        prod = 1
        for a in cands:
            if a in mesh.axis_names and a not in used \
                    and x.shape[dim] % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        used.update(chosen)
        entries.append(None if not chosen
                       else (chosen[0] if len(chosen) == 1
                             else tuple(chosen)))
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))

DEFAULT_RULES = {
    "embed": ("data",),       # FSDP/ZeRO-3 for weight matrices
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
}

NO_FSDP_RULES = {**DEFAULT_RULES, "embed": ()}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for(axes: tuple, shape: tuple, mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for logical, size in zip(axes, shape):
        if logical is None:
            out.append(None)
            continue
        want = [a for a in rules.get(logical, ()) if a not in used
                and a in mesh.axis_names]
        # greedy prefix that divides the dim size
        chosen = []
        prod = 1
        for a in want:
            if size % (prod * _axis_size(mesh, a)) == 0:
                chosen.append(a)
                prod *= _axis_size(mesh, a)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
            used.add(chosen[0])
        else:
            out.append(tuple(chosen))
            used.update(chosen)
    return P(*out)


def param_shardings(axes_tree, shape_tree, mesh: Mesh,
                    rules: Optional[dict] = None):
    """Tree of NamedSharding matching a param (or same-shaped state) tree."""
    is_axes_leaf = lambda x: isinstance(x, tuple)  # noqa: E731

    def one(axes, leaf):
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, rules))

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes_leaf)


def batch_spec(mesh: Mesh, *, batch_size: int) -> P:
    """Batch-dim sharding: as much DP as divides the global batch."""
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and batch_size % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def _dim_spec(size: int, mesh: Mesh, prefer: list, used: set):
    """Greedy: shard `size` over the first unused axes that divide it."""
    chosen = []
    prod = 1
    for a in prefer:
        if a in mesh.axis_names and a not in used \
                and size % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    for a in chosen:
        used.add(a)
    if not chosen:
        return None
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


def _batch_entry(mesh: Mesh, size: int):
    """Greedy DP entry for a batch-like dim, plus the axes it consumed."""
    chosen, prod = [], 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and size > 1 \
                and size % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    entry = (None if not chosen
             else (chosen[0] if len(chosen) == 1 else tuple(chosen)))
    return entry, set(chosen)


def kv_cache_spec(shape: tuple, mesh: Mesh, *, lead: int = 0) -> P:
    """PartitionSpec of a KV-cache leaf [*lead, B, Hkv, Nmax, *feat].

    Matches what the cache's CONSUMERS (`softmax_attention` inside the
    decode step) can use: kv heads over 'model' when they divide it, else
    the SEQUENCE dim over 'model' (each device scans its slice of the
    timeline; softmax's max/sum become clean partial reductions). The
    head_dim/Dv trailing dim is deliberately never sharded — the old
    last-dim-first generic policy put 'model' there, which no consumer
    matmul could keep, and the partitioner answered with involuntary full
    rematerializations of cache-sized tensors every step (the 3 SOFTMAX
    32k-decode warnings, ROADMAP).
    """
    entries = [None] * len(shape)
    b_entry, used = _batch_entry(mesh, shape[lead])
    entries[lead] = b_entry
    tp = model_axis_size(mesh)
    if tp > 1 and len(shape) > lead + 2:
        hkv, nmax = shape[lead + 1], shape[lead + 2]
        if hkv % tp == 0:
            entries[lead + 1] = "model"
        elif nmax % tp == 0:
            entries[lead + 2] = "model"
    return P(*entries)


def constrain_kv_cache(x, *, lead: int = 0):
    """with_sharding_constraint to `kv_cache_spec` (no-op without a mesh).

    Applied by the softmax decode/prefill step to the freshly-updated
    cache so the in-step tensors keep the committed inter-step layout."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, kv_cache_spec(x.shape, mesh, lead=lead))


# base ndims of the Moments fields (batch, kv-heads leading): any extra
# leading axes on a state leaf are layer-stacking (scan-over-layers groups)
_MOMENT_NDIM = {"m0": 3, "m1": 4, "m2": 5, "g0": 2, "g1": 3, "g2": 4}


def _moments_shardings(mom, mesh: Mesh):
    """Shardings of a Moments(-shaped) state: the SAME partitioning the
    shard_map-wrapped kernels use (repro.kernels.sharded) — for decode,
    prefill, AND the feature-TP trainable custom_vjp residual (the
    Dv-blocked backward consumes the carry in exactly this layout) — so
    the committed inter-step layout and every kernel launch agree with
    zero resharding:

      heads mode    (Hkv % tp == 0): kv-head dim over 'model';
      feature mode  (else, Dv % tp == 0): value-feature (last) dim of
                    m0/m1/m2 over 'model', scalar g-moments REPLICATED
                    across 'model' (they are Dv-times smaller than their m
                    partners; replicating them keeps the decode step's
                    denominator exact shard-locally instead of resharding
                    g2 over the ICI every token).
    """
    tp = model_axis_size(mesh)
    fields = type(mom)._fields if hasattr(type(mom), "_fields") else \
        tuple(_MOMENT_NDIM)

    hkv = None
    dv = None
    lead = mom[0].ndim - _MOMENT_NDIM["m0"]
    if lead >= 0:
        hkv = mom[0].shape[lead + 1]
        dv = mom[0].shape[-1]
    heads_mode = tp > 1 and hkv is not None and hkv % tp == 0
    feat_mode = (not heads_mode and tp > 1 and dv is not None
                 and dv % tp == 0)

    def one(name, leaf):
        nd = _MOMENT_NDIM.get(name)
        if nd is None or leaf.ndim < nd:
            return NamedSharding(mesh, P())
        ld = leaf.ndim - nd
        entries = [None] * leaf.ndim
        entries[ld], _ = _batch_entry(mesh, leaf.shape[ld])
        if heads_mode:
            entries[ld + 1] = "model"
        elif feat_mode and name in ("m0", "m1", "m2"):
            entries[-1] = "model"
        return NamedSharding(mesh, P(*entries))

    return type(mom)(*(one(n, leaf) for n, leaf in zip(fields, mom)))


def decode_state_shardings(state_shapes, mesh: Mesh, *, batch: int):
    """Shard a decode-state tree (KV caches / fastmax moments / ssm states).

    Structured nodes get consumer-matched policies — `Moments` the
    shard_map kernel partitioning (`_moments_shardings`), `KVCache`
    k/v/mask the `kv_cache_spec` head-or-sequence layout. Generic leaves
    (ssm/xlstm states) keep the greedy policy: batch -> (pod, data) when
    divisible, then the LARGEST remaining dims -> remaining mesh axes
    (model first), realizing full feature sharding ("data"+"model") for
    batch=1 long-context decode.
    """
    from repro.attention.state import KVCache
    from repro.core.fastmax import Moments

    def generic(leaf):
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        out = []
        # dim 0 = batch
        b_entry, used = _batch_entry(mesh, shape[0])
        out.append(b_entry)
        # remaining dims: LAST dim first (feature dims combine locally when
        # sharded; scan-sliced dims must stay unsharded), then largest
        order = sorted(range(1, len(shape)),
                       key=lambda i: (0 if i == len(shape) - 1 else 1,
                                      -shape[i]))
        specs = {i: None for i in order}
        for i in order:
            specs[i] = _dim_spec(shape[i], mesh,
                                 ["model", "data", "pod"], used)
        out.extend(specs[i] for i in range(1, len(shape)))
        return NamedSharding(mesh, P(*out))

    def kv_shardings(kv):
        lead = kv.k.ndim - 4
        def one(name, leaf):
            if name in ("k", "v", "mask"):
                return NamedSharding(
                    mesh, kv_cache_spec(leaf.shape, mesh, lead=lead))
            return NamedSharding(mesh, P())  # length scalar
        return type(kv)(*(one(n, leaf)
                          for n, leaf in zip(type(kv)._fields, kv)))

    def node(x):
        if isinstance(x, Moments):
            return _moments_shardings(x, mesh)
        if isinstance(x, KVCache):
            return kv_shardings(x)
        return jax.tree.map(generic, x)

    return jax.tree.map(
        node, state_shapes,
        is_leaf=lambda x: isinstance(x, (Moments, KVCache)))
