"""Sharding: logical-axis rules -> PartitionSpecs (DP/FSDP/TP/EP/SP)."""
from repro.sharding.rules import (  # noqa: F401
    DEFAULT_RULES,
    batch_spec,
    decode_state_shardings,
    param_shardings,
    spec_for,
)
