"""repro — FAST (Factorizable Attention) production framework in JAX."""
__version__ = "1.0.0"
