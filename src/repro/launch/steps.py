"""train_step / serve_step factories shared by the launcher and the dry-run.

train_step = fwd + bwd + global-norm clip + optimizer update (donated
params/opt buffers). serve_step = one decode token for the whole model
(donated state). Both are pure functions closed over the static ModelConfig.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, model_loss
from repro.models.transformer import ModelConfig
from repro.optim import clip_by_global_norm, make_optimizer, warmup_cosine

__all__ = ["make_train_step", "make_serve_step", "make_prefill_step",
           "pick_optimizer"]


def pick_optimizer(cfg: ModelConfig, n_params: int, *, lr=3e-4,
                   total_steps=100_000):
    """Policy: Lion (2B/param state) for >=100B-param configs, AdamW below."""
    name = "lion" if n_params >= 100e9 else "adamw"
    lr_fn = warmup_cosine(lr, min(2000, total_steps // 10), total_steps)
    return name, make_optimizer(name, lr_fn)


def make_train_step(cfg: ModelConfig, optimizer, *, clip_norm: float = 1.0):
    _, opt_update = optimizer

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model_loss, has_aux=True)(params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt_update(grads, opt_state, params)
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "gnorm": gnorm.astype(jnp.float32), **metrics}
        return new_params, new_opt, out_metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    def serve_step(params, state, token, position, enc_out=None):
        p = params["decoder"] if cfg.encoder_layers > 0 else params
        logits, new_state = decode_step(p, state, token, cfg,
                                        position=position, enc_out=enc_out)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_state

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Prompt prefill: full forward that primes the decode state (fastmax:
    chunked moment scan — linear in prompt; softmax: KV-cache fill)."""
    from repro.models.transformer import lm_prefill

    def prefill_step(params, state, tokens, enc_out=None):
        p = params["decoder"] if cfg.encoder_layers > 0 else params
        logits, new_state = lm_prefill(p, tokens, cfg, state, enc_out=enc_out)
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return last, new_state

    return prefill_step
