import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without real hardware:
  * builds the production mesh (16x16 single pod / 2x16x16 multi-pod) on 512
    placeholder host devices (XLA_FLAGS above, set BEFORE any jax import),
  * lowers train_step / prefill_step / serve_step against ShapeDtypeStruct
    inputs (zero allocation) with the full DP/FSDP/TP/EP sharding rules,
  * compiles, prints memory_analysis() (proves the per-device footprint) and
    cost_analysis(), and extracts trip-count-corrected matmul FLOPs +
    per-kind collective bytes from the optimized HLO (hlo_analysis.py),
  * writes one JSON per cell under --out for §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import contextlib
import json
import sys
import tempfile
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.attention import AttentionSpec
from repro.configs import SHAPES, all_arch_ids, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step, pick_optimizer)
from repro.models import (decode_state_specs, init_model, input_specs)
from repro.sharding import (batch_spec, decode_state_shardings,
                            param_shardings)

# v5e constants for the roofline terms (per task spec)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_REMAT_MSG = "Involuntary full rematerialization"


@contextlib.contextmanager
def _capture_xla_warnings(out: dict):
    """Capture fd-2 around lower/compile: the SPMD partitioner logs
    "Involuntary full rematerialization" from C++ (invisible to Python
    logging). Records count + first lines in `out` and re-emits everything
    to the real stderr, so the sharding-health signal becomes a machine-
    checkable part of the dry-run result JSON (--assert-no-remat gates on
    it)."""
    sys.stderr.flush()
    try:
        saved = os.dup(2)
    except OSError:
        yield
        return
    with tempfile.TemporaryFile(mode="w+b") as tmp:
        os.dup2(tmp.fileno(), 2)
        try:
            yield
        finally:
            # re-emit + record in the finally so a raising cell still
            # surfaces XLA's stderr (compile errors!) and its remat count
            sys.stderr.flush()
            os.dup2(saved, 2)
            os.close(saved)
            tmp.seek(0)
            text = tmp.read().decode("utf-8", "replace")
            if text:
                sys.stderr.write(text)
                sys.stderr.flush()
            remat = [ln for ln in text.splitlines() if _REMAT_MSG in ln]
            out["xla_remat"] = {
                "count": len(remat),
                "lines": [ln[:400] for ln in remat[:8]],
            }


@contextlib.contextmanager
def _kernel_cell_env(cfg):
    """kernel-impl cells must exercise the kernel protocol, not the
    platform fallback: REPRO_DECODE_KERNEL=1 forces the (shard_map-wrapped
    under the mesh) Pallas decode path, in interpret mode on this CPU host
    — the compiled HLO still proves the partitioning. An explicit
    REPRO_DECODE_KERNEL in the environment wins."""
    prev = os.environ.get("REPRO_DECODE_KERNEL")
    if prev is None and cfg.attn.family in ("fastmax", "hybrid") \
            and cfg.attn.impl == "kernel":
        os.environ["REPRO_DECODE_KERNEL"] = "1"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_DECODE_KERNEL", None)


def _tree_size_bytes(tree) -> int:
    return sum(int(jnp.prod(jnp.asarray(x.shape)) * x.dtype.itemsize)
               if hasattr(x, "shape") else 0
               for x in jax.tree.leaves(tree))


def _opt_shardings(opt_shapes, param_sh, mesh):
    """Optimizer state shardings: moments/master like params; step replicated.
    (Lion m / AdamW m,v,master all have param shapes.)"""
    rep = NamedSharding(mesh, P())

    def like_params(sub):
        if sub is None:
            return None
        return jax.tree.map(lambda _, s: s, sub, param_sh)

    from repro.optim.optimizers import OptState
    return OptState(
        step=rep,
        m=like_params(opt_shapes.m),
        v=like_params(opt_shapes.v),
        master=like_params(opt_shapes.master),
    )


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             attn: AttentionSpec | str | None = None, donate: bool = True,
             extra_cfg: dict | None = None, cp: int = 1) -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    overrides = dict(extra_cfg or {})
    if attn:
        overrides["attn"] = (AttentionSpec.parse(attn)
                             if isinstance(attn, str) else attn)
    cfg = get_config(arch, **overrides)

    if shape_name == "long_500k" and cfg.attn.family == "softmax" \
            and cfg.family not in ("ssm", "hybrid"):
        return {"arch": arch, "shape": shape_name, "skipped":
                "long_500k needs sub-quadratic attention; softmax baseline "
                "is pure full attention (DESIGN.md §Arch-applicability)"}

    # record this cell's attention routing decisions (the _log_once lines:
    # backend reroutes, kernel shard_map plans, jnp fallbacks) so the
    # result JSON is machine-checkable (--assert-kernel-route), and the
    # autotune lookups so the result also pins WHICH kernel schedule each
    # launch traced with (cache hit/miss next to attn_routing)
    from repro.attention.registry import _LOGGED
    from repro.kernels import autotune
    _LOGGED.clear()
    autotune.clear_lookups()

    mesh = make_production_mesh(multi_pod=multi_pod, cp=cp)
    n_chips = mesh.devices.size
    if cp > 1 and shape.seq_len % cp:
        raise ValueError(f"--cp {cp} must divide seq_len={shape.seq_len}")
    key = jax.random.PRNGKey(0)
    params_shapes, axes = init_model(key, cfg, abstract=True)
    n_params = sum(int(jnp.prod(jnp.asarray(x.shape)))
                   for x in jax.tree.leaves(params_shapes))

    xla_diag: dict = {}
    with _capture_xla_warnings(xla_diag), _kernel_cell_env(cfg), mesh:
        param_sh = param_shardings(axes, params_shapes, mesh)

        if shape.kind == "train":
            opt_name, optimizer = pick_optimizer(cfg, n_params)
            opt_init, _ = optimizer
            opt_shapes = jax.eval_shape(opt_init, params_shapes)
            opt_sh = _opt_shardings(opt_shapes, param_sh, mesh)
            bspec = batch_spec(mesh, batch_size=shape.global_batch)
            batch_shapes = input_specs(cfg, global_batch=shape.global_batch,
                                       seq_len=shape.seq_len, kind="train")
            batch_sh = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(*(list(bspec) + [None] * (len(s.shape) - 1)))),
                batch_shapes)
            step = make_train_step(cfg, optimizer)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_shapes, opt_shapes, batch_shapes)
        elif shape.kind == "prefill":
            state_shapes = decode_state_specs(cfg, shape.global_batch,
                                              shape.seq_len)
            state_sh = decode_state_shardings(state_shapes, mesh,
                                              batch=shape.global_batch)
            bspec = batch_spec(mesh, batch_size=shape.global_batch)
            tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32)
            tok_sh = NamedSharding(mesh, P(*(list(bspec) + [None])))
            step = make_prefill_step(cfg)
            args = [params_shapes, state_shapes, tok]
            in_sh = [param_sh, state_sh, tok_sh]
            if cfg.encoder_layers > 0:
                enc = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                    cfg.adtype())
                args.append(enc)
                in_sh.append(NamedSharding(mesh,
                                           P(*(list(bspec) + [None, None]))))
            jitted = jax.jit(
                step, in_shardings=tuple(in_sh),
                out_shardings=(NamedSharding(mesh, P(*list(bspec))),
                               state_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(*args)
        else:  # decode
            state_shapes = decode_state_specs(cfg, shape.global_batch,
                                              shape.seq_len)
            state_sh = decode_state_shardings(state_shapes, mesh,
                                              batch=shape.global_batch)
            bspec = batch_spec(mesh, batch_size=shape.global_batch)
            tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            tok_sh = NamedSharding(
                mesh, P(*list(bspec)) if shape.global_batch > 1 else P(None))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_serve_step(cfg)
            args = [params_shapes, state_shapes, tok, pos]
            in_sh = [param_sh, state_sh, tok_sh, NamedSharding(mesh, P())]
            if cfg.encoder_layers > 0:
                enc = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                    cfg.adtype())
                args.append(enc)
                in_sh.append(NamedSharding(
                    mesh, P(*((list(bspec) if shape.global_batch > 1
                               else [None]) + [None, None]))))
            jitted = jax.jit(
                step, in_shardings=tuple(in_sh),
                out_shardings=(tok_sh, state_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(*args)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):   # older JAX returns [dict] per device program
        cost = cost[0] if cost else {}
    hlo = analyze_hlo(compiled.as_text())

    # --- roofline terms (see EXPERIMENTS.md §Roofline) ---------------------
    # the compiled module is the PER-DEVICE program: flops/bytes are per chip
    flops_dev = hlo["matmul_flops"]
    coll = hlo["collective_bytes"]
    hbm = hlo["hbm_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hbm / HBM_BW                           # per-chip stream time
    collective_s = coll / ICI_BW                      # per-chip link time

    # MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (serve)
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    ax_flat = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    total_p = routed_p = embed_p = 0
    for (path, leaf), ax in zip(flat, ax_flat):
        npx = 1
        for d in leaf.shape:
            npx *= int(d)
        total_p += npx
        if "experts" in ax:
            routed_p += npx
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "embed":
            embed_p += npx
    active_p = total_p - (0 if cfg.n_experts == 0 else
                          routed_p * (1.0 - cfg.moe_top_k / cfg.n_experts))
    if not cfg.tie_embeddings:
        active_p -= embed_p
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    model_flops = (6.0 if shape.kind == "train" else 2.0) * active_p * tokens
    useful_ratio = model_flops / max(1.0, flops_dev * n_chips)

    cp_boundary = None
    if cp > 1 and shape.kind == "train":
        # modeled per-boundary collective bytes of the context-parallel
        # carry exchange, next to the ring-attention O(N·D) alternative —
        # the gate asserts the carry payload is independent of N
        from repro.kernels.sharded import cp_boundary_model
        cp_boundary = cp_boundary_model(
            n=shape.seq_len, b=shape.global_batch, hkv=cfg.n_kv_heads,
            d=cfg.head_dim, dv=cfg.head_dim, p=cfg.attn.p, cp=cp)

    out = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "cp": cp,
        "cp_boundary": cp_boundary,
        "xla_remat": xla_diag.get("xla_remat", {"count": 0, "lines": []}),
        "attn_routing": sorted(_LOGGED),
        "attn_schedule": autotune.snapshot_lookups(),
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_chips": int(n_chips),
        "attn_backend": cfg.attn.legacy_name,   # result-JSON back-compat key
        "attn_spec": str(cfg.attn),
        "n_params": int(n_params),
        "param_bytes_global": _tree_size_bytes(params_shapes),
        "memory_analysis": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "alias_size": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed")},
        "hlo": {k: float(v) for k, v in hlo.items()},
        "model_flops": model_flops,
        "active_params": float(active_p),
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "useful_flops_ratio": useful_ratio,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "compile_seconds": time.time() - t0,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--attn", default=None,
                    help="attention operator (AttentionSpec.parse name, "
                         "e.g. softmax, fastmax2, fastmax2-kernel)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree: trade the 'model' mesh "
                         "axis for a 'seq' axis of this size (train cells; "
                         "fastmax routes shard_map[seq])")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--assert-no-remat", action="store_true",
                    help="fail a cell if the SPMD partitioner logged any "
                         "'Involuntary full rematerialization' (sharding-"
                         "annotation health gate; see ROADMAP serve-path "
                         "item)")
    ap.add_argument("--assert-kernel-route", action="store_true",
                    help="fail a cell if the decode protocol fell back to "
                         "the jnp moment step (a '-> jnp' routing line): "
                         "proves the shard_map-wrapped Pallas kernels are "
                         "the decode path at this mesh/shape")
    args = ap.parse_args()

    archs = all_arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}" \
                    + (f"__{args.attn}" if args.attn else "") \
                    + (f"__cp{args.cp}" if args.cp > 1 else "")
                try:
                    res = run_cell(arch, shape, multi_pod=multi,
                                   attn=args.attn, cp=args.cp)
                    status = "SKIP" if "skipped" in res else "OK"
                    gate_errs = []
                    n_remat = res.get("xla_remat", {}).get("count", 0)
                    if args.assert_no_remat and n_remat:
                        gate_errs.append(
                            f"{n_remat} involuntary full "
                            f"rematerialization warning(s)")
                    routing = res.get("attn_routing", [])
                    # fallback lines: the decode protocol's "-> jnp" moment
                    # step AND the trainable path's "-> chunked scan"
                    # (feature-TP training must stay on the shard_map
                    # Pallas kernels); the benign "-> interpret mode"
                    # platform note is not a fallback
                    falls = [ln for ln in routing
                             if "-> jnp" in ln or "-> chunked scan" in ln]
                    routed = any("kernel shard_map[" in ln
                                 for ln in routing)
                    if args.assert_kernel_route and status == "OK":
                        # require the POSITIVE shard_map routing line too —
                        # an empty/disabled routing record must not pass
                        # the gate vacuously
                        if falls:
                            gate_errs.append("attention fell back off the "
                                             "kernels: " + falls[0])
                        elif not routed:
                            gate_errs.append(
                                "no shard_map kernel routing line recorded "
                                "(REPRO_DECODE_KERNEL disabled, or a "
                                "non-kernel cell?)")
                    if gate_errs:
                        status = "FAIL"
                        failures += 1
                        res["error"] = "; ".join(gate_errs)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    status = "FAIL"
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=2)
                if not args.quiet:
                    line = f"[{status}] {tag}"
                    if status == "OK":
                        r = res["roofline"]
                        line += (f"  compute={r['compute_s']:.3e}s "
                                 f"memory={r['memory_s']:.3e}s "
                                 f"collective={r['collective_s']:.3e}s "
                                 f"dominant={r['dominant']} "
                                 f"compile={res['compile_seconds']:.0f}s")
                        ma = res["memory_analysis"]
                        line += (f" argbytes/dev={ma['argument_size']} "
                                 f"temp/dev={ma['temp_size']}")
                    elif status == "FAIL":
                        line += "  " + res["error"][:160]
                    print(line, flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
