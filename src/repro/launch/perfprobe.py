import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf probe: compile one dry-run cell and print the flops breakdown by
op_name (+ roofline terms). The 'profiler' for the §Perf loop.

Usage: python -m repro.launch.perfprobe --arch granite-20b --shape train_4k
"""
import argparse

from repro.launch import dryrun as dr
from repro.launch.hlo_analysis import flops_breakdown


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--attn", default=None)
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    # reuse run_cell but keep the compiled text
    import json
    import jax
    orig_analyze = dr.analyze_hlo
    captured = {}

    def capture(text):
        captured["hlo"] = text
        return orig_analyze(text)

    dr.analyze_hlo = capture
    res = dr.run_cell(args.arch, args.shape, multi_pod=args.multi,
                      attn=args.attn)
    print(json.dumps(res.get("roofline", res), indent=2))
    print({k: f"{v:.3e}" for k, v in res.get("hlo", {}).items()
           if k.startswith("coll_") and v})
    ma = res.get("memory_analysis", {})
    print(f"argbytes/dev={ma.get('argument_size')} "
          f"temp/dev={ma.get('temp_size')}")
    total = res["hlo"]["matmul_flops"]
    print(f"\nper-device matmul flops: {total:.3e}; breakdown:")
    for name, fl in flops_breakdown(captured["hlo"], top=args.top):
        print(f"  {fl:12.3e} ({100*fl/total:5.1f}%)  {name[:110]}")
    if args.dump_hlo:
        open(args.dump_hlo, "w").write(captured["hlo"])


if __name__ == "__main__":
    main()
