"""Trip-count-corrected analysis of compiled (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE (verified on this
container: a 10-step scan reports 1/10th the flops of the unrolled loop), so
every quantity here is computed by walking the computation graph and
multiplying `while` bodies by their trip counts — taken from the while op's
`backend_config known_trip_count` (fallback: the loop condition's compare
constant).

Extracted per module:
  * matmul_flops      — 2 * prod(out) * prod(contracting) over `dot` ops
  * collective_bytes  — operand bytes of all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute
                        (+ async -start forms), broken out per kind
  * hbm_bytes         — Σ (operand + output bytes) over ops in control
                        computations (fusion bodies excluded) — a
                        fusion-granularity proxy for HBM traffic
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "flops_breakdown"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# type part matched lazily: tuple types may contain /*index=N*/ comments
_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-~!]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes_dims(type_str: str) -> Tuple[int, Optional[List[int]]]:
    """Bytes of a (possibly tuple) type string; dims if a single array."""
    total = 0
    dims = None
    matches = list(_SHAPE_RE.finditer(type_str))
    for m in matches:
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    if len(matches) == 1:
        dims = [int(d) for d in matches[0].group(2).split(",") if d]
    return total, dims


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.ops: List[dict] = []
        self.symbols: Dict[str, Tuple[int, Optional[List[int]]]] = {}


def _first_paren_group(line: str, start: int) -> str:
    depth = 0
    out = []
    for ch in line[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            out.append(ch)
    return "".join(out)


def _parse(text: str):
    comps: Dict[str, _Comp] = {}
    entry_name = None
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{") and "(" in line:
                m = re.match(r"(ENTRY\s+)?%?([\w\.\-~!]+)", line)
                if m:
                    cur = _Comp(m.group(2))
                    comps[cur.name] = cur
                    if m.group(1):
                        entry_name = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        nbytes, dims = _type_bytes_dims(type_str)
        cur.symbols[name] = (nbytes, dims)
        operand_str = _first_paren_group(line, m.end() - 1)
        operands = re.findall(r"%([\w\.\-~!]+)", operand_str)
        cur.ops.append({"name": name, "opcode": opcode, "bytes": nbytes,
                        "dims": dims, "operands": operands, "line": line})
    return comps, entry_name


def _trip_count(line: str, comps, cond_name: Optional[str]) -> int:
    m = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', line)
    if m:
        return int(m.group(1))
    if cond_name and cond_name in comps:
        best = 1
        for op in comps[cond_name].ops:
            for c in re.finditer(r"constant\((\d+)\)", op["line"]):
                best = max(best, int(c.group(1)))
        return best
    return 1


def analyze_hlo(text: str) -> Dict[str, float]:
    comps, entry_name = _parse(text)
    if entry_name is None:
        entry_name = list(comps)[-1]

    fused = set()
    for comp in comps.values():
        for op in comp.ops:
            if op["opcode"] == "fusion":
                for c in re.findall(r"calls=%?([\w\.\-~!]+)", op["line"]):
                    fused.add(c)

    memo: Dict[str, Dict[str, float]] = {}

    def operand_bytes(comp: _Comp, op) -> int:
        return sum(comp.symbols.get(o, (0, None))[0] for o in op["operands"])

    def walk(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        zero = {"matmul_flops": 0.0, "hbm_bytes": 0.0,
                **{f"coll_{k}": 0.0 for k in _COLLECTIVES}}
        memo[name] = zero
        comp = comps.get(name)
        if comp is None:
            return zero
        out = dict(zero)
        for op in comp.ops:
            opc = op["opcode"]
            if opc == "while":
                body = re.search(r"body=%?([\w\.\-~!]+)", op["line"])
                cond = re.search(r"condition=%?([\w\.\-~!]+)", op["line"])
                trips = _trip_count(op["line"], comps,
                                    cond.group(1) if cond else None)
                if body and body.group(1) in comps:
                    sub = walk(body.group(1))
                    for k, v in sub.items():
                        out[k] += trips * v
                out["hbm_bytes"] += op["bytes"]
                continue
            if opc in ("call", "conditional"):
                refs = re.findall(r"(?:calls|to_apply)=%?([\w\.\-~!]+)",
                                  op["line"])
                bm = re.search(r"branch_computations=\{([^}]*)\}", op["line"])
                if bm:
                    refs += [b.strip().lstrip("%")
                             for b in bm.group(1).split(",")]
                for c in refs:
                    if c in comps:
                        sub = walk(c)
                        for k, v in sub.items():
                            out[k] += v
                continue
            if opc == "dot":
                prod_out = 1
                for d in (op["dims"] or []):
                    prod_out *= d
                contract = 1
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               op["line"])
                if lc and op["operands"]:
                    lhs_dims = comp.symbols.get(op["operands"][0],
                                                (0, None))[1] or []
                    for i in [int(x) for x in lc.group(1).split(",") if x]:
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                out["matmul_flops"] += 2.0 * prod_out * contract
            base = opc[:-6] if opc.endswith("-start") else opc
            if base in _COLLECTIVES:
                opb = operand_bytes(comp, op) or op["bytes"]
                out[f"coll_{base}"] += opb
            if name not in fused and opc not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "copy-done", "all-reduce-done",
                    "all-gather-done", "collective-permute-done"):
                out["hbm_bytes"] += op["bytes"] + operand_bytes(comp, op)
        memo[name] = out
        return out

    totals = walk(entry_name)
    totals["collective_bytes"] = sum(totals[f"coll_{k}"]
                                     for k in _COLLECTIVES)
    return totals


def flops_breakdown(text: str, top: int = 25):
    """Per-op_name matmul-flops attribution (trip-count aware) — the
    'profile' for the §Perf loop on a dry-run-only container."""
    comps, entry_name = _parse(text)
    if entry_name is None:
        entry_name = list(comps)[-1]

    from collections import defaultdict
    acc = defaultdict(float)

    def op_name(line: str) -> str:
        m = re.search(r'op_name="([^"]+)"', line)
        return m.group(1) if m else "<?>"

    def walk(name: str, mult: float, seen):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        for op in comp.ops:
            opc = op["opcode"]
            if opc == "while":
                body = re.search(r"body=%?([\w\.\-~!]+)", op["line"])
                cond = re.search(r"condition=%?([\w\.\-~!]+)", op["line"])
                trips = _trip_count(op["line"], comps,
                                    cond.group(1) if cond else None)
                if body and body.group(1) in comps:
                    walk(body.group(1), mult * trips, seen | {name})
                continue
            if opc in ("call", "conditional"):
                for c in re.findall(r"(?:calls|to_apply)=%?([\w\.\-~!]+)",
                                    op["line"]):
                    if c in comps:
                        walk(c, mult, seen | {name})
                continue
            if opc == "dot":
                prod_out = 1
                for d in (op["dims"] or []):
                    prod_out *= d
                contract = 1
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               op["line"])
                if lc and op["operands"]:
                    lhs_dims = comp.symbols.get(op["operands"][0],
                                                (0, None))[1] or []
                    for i in [int(x) for x in lc.group(1).split(",") if x]:
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                acc[op_name(op["line"])] += mult * 2.0 * prod_out * contract

    walk(entry_name, 1.0, frozenset())
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]
