"""Serving driver: batched prefill + decode with O(1)-in-context state.

With fastmax backends the per-sequence state is the moment tuple — constant
in context length — so a 32k or 500k context costs the same per decoded
token (the paper's asymptotic claim, made concrete; see
examples/long_context.py). Softmax baseline uses a (sequence-sharded at
scale) KV cache.

Two paths:

  default          `generate()` — one static batch, whole-prompt prefill,
                   lockstep greedy decode (optionally eos-early-stopped).
  --serve-engine   `repro.serve.ServeEngine` — continuous batching over a
                   slot pool: staggered admissions, chunked prefill mixed
                   with decode, per-request streaming, plus the fault
                   envelope (--max-queue backpressure, --ttft-deadline /
                   --deadline timeouts; the driver prints the lifecycle
                   counters from engine.stats()). See docs/serving.md.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 64 --gen 32 [--serve-engine --slots 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_decode_state, init_model

# jitted prefill/step per config — reused across generate() calls so a
# warmup call actually warms the timed call (cfg is frozen/hashable)
_JIT_CACHE: dict = {}


def _jitted_steps(cfg):
    fns = _JIT_CACHE.get(cfg)
    if fns is None:
        fns = (jax.jit(make_prefill_step(cfg)), jax.jit(make_serve_step(cfg)))
        _JIT_CACHE[cfg] = fns
    return fns


def generate(params, cfg, prompts: jnp.ndarray, n_gen: int,
             max_len: int | None = None, enc_out=None,
             eos_id: int | None = None):
    """prompts: [B, P] int32. Greedy decode of n_gen tokens.

    With `eos_id`, a sequence that emits it is frozen: its remaining
    positions are filled with `eos_id`, and the loop exits early once
    every sequence is done (per-sequence done mask).
    """
    b, plen = prompts.shape
    state = init_decode_state(cfg, b, (max_len or (plen + n_gen)))
    prefill, step = _jitted_steps(cfg)
    tok, state = prefill(params, state, prompts, *(
        [enc_out] if enc_out is not None else []))
    done = (tok == eos_id) if eos_id is not None else None
    out = [tok]
    for i in range(n_gen - 1):
        if done is not None and bool(done.all()):
            out.extend([jnp.full_like(tok, eos_id)] * (n_gen - 1 - i))
            break
        pos = jnp.asarray(plen + i, jnp.int32)  # traced: no retrace per step
        tok, state = step(params, state, tok, pos, *(
            [enc_out] if enc_out is not None else []))
        if done is not None:
            tok = jnp.where(done, eos_id, tok)
            done = done | (tok == eos_id)
        out.append(tok)
    return jnp.stack(out, axis=1)


def _submit_all(eng, prompts, n_gen, args):
    """Submit the batch, absorbing backpressure: a bounded queue
    (--max-queue) rejects at submit time with EngineOverloaded, and we
    drain a tick and retry rather than crash the driver."""
    from repro.serve import EngineOverloaded

    rids = []
    for p in np.asarray(prompts):
        while True:
            try:
                rids.append(eng.submit(
                    p, n_gen, ttft_deadline=args.ttft_deadline,
                    deadline=args.deadline))
                break
            except EngineOverloaded:
                eng.step()   # make room, then retry this prompt
    return rids


def _run_engine(params, cfg, prompts, n_gen, args):
    """Continuous-batching path: submit the batch as staggered requests."""
    from repro.serve import ServeEngine

    max_len = prompts.shape[1] + n_gen
    eng = ServeEngine(
        params, cfg, max_slots=args.slots, max_len=max_len,
        eos_id=args.eos_id, policy=args.policy,
        prefix_cache_bytes=args.prefix_cache_mb << 20,
        max_queue=args.max_queue)
    rids = _submit_all(eng, prompts, n_gen, args)
    outs = eng.run()
    return eng, [outs.get(r, []) for r in rids]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--serve-engine", action="store_true",
                    help="continuous batching via repro.serve.ServeEngine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="fcfs", choices=("fcfs", "lpf"))
    ap.add_argument("--prefix-cache-mb", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=256,
                    help="bounded admission queue depth; submits beyond it "
                         "raise EngineOverloaded (0 = unbounded)")
    ap.add_argument("--ttft-deadline", type=float, default=None,
                    help="seconds from submit to first token before the "
                         "request is timed out")
    ap.add_argument("--deadline", type=float, default=None,
                    help="seconds from submit to completion before the "
                         "request is timed out")
    args = ap.parse_args(argv)

    import dataclasses

    from repro.attention import AttentionSpec
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn:
        cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(args.attn))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    enc_out = None
    if cfg.encoder_layers > 0:
        from repro.models.encdec import encode
        frames = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            cfg.adtype())
        enc_out = encode(params, frames, cfg)

    if args.serve_engine:
        # warmup batch traces the engine's tick variants; the timed batch
        # reuses the same engine (and therefore its jit caches)
        eng, _ = _run_engine(params, cfg, prompts, args.gen, args)
        t0 = time.monotonic()
        rids = _submit_all(eng, prompts, args.gen, args)
        outs = eng.run()
        dt = time.monotonic() - t0
        n_tok = sum(len(outs.get(r, [])) for r in rids)
        ttfts = sorted(f.ttft for f in eng.history[-len(rids):]
                       if f.ttft is not None)
        ttft_ms = (f"{ttfts[len(ttfts) // 2] * 1e3:.1f}ms"
                   if ttfts else "n/a")
        st = eng.stats()
        print(f"[engine] generated {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s)  ttft p50 {ttft_ms}  "
              f"slot bytes {eng.slots.state_bytes_per_slot()}  sample: "
              f"{outs[rids[0]][:16]}")
        print(f"[engine] lifecycle: finished {st['finished']}  "
              f"failed {st['failed']}  cancelled {st['cancelled']}  "
              f"timed_out {st['timed_out']}  rejected {st['rejected']}  "
              f"shed {st['shed']}  quarantined {st['quarantined']}  "
              f"ticks {st['ticks']}")
        return

    # warmup: trace + compile out of the timed region (jits are cached
    # per-config, so the timed call reuses them)
    toks = jax.block_until_ready(
        generate(params, cfg, prompts, args.gen, enc_out=enc_out,
                 eos_id=args.eos_id))
    t0 = time.monotonic()
    toks = jax.block_until_ready(
        generate(params, cfg, prompts, args.gen, enc_out=enc_out,
                 eos_id=args.eos_id))
    dt = time.monotonic() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)  sample: "
          f"{np.asarray(toks[0][:16])}")


if __name__ == "__main__":
    main()
