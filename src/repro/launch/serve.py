"""Serving driver: batched prefill + decode with O(1)-in-context state.

With fastmax backends the per-sequence state is the moment tuple — constant
in context length — so a 32k or 500k context costs the same per decoded
token (the paper's asymptotic claim, made concrete; see
examples/long_context.py). Softmax baseline uses a (sequence-sharded at
scale) KV cache.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_decode_state, init_model


def generate(params, cfg, prompts: jnp.ndarray, n_gen: int,
             max_len: int | None = None, enc_out=None):
    """prompts: [B, P] int32. Greedy decode of n_gen tokens."""
    b, plen = prompts.shape
    state = init_decode_state(cfg, b, (max_len or (plen + n_gen)))
    prefill = jax.jit(make_prefill_step(cfg))
    step = jax.jit(make_serve_step(cfg))
    tok, state = prefill(params, state, prompts, *(
        [enc_out] if enc_out is not None else []))
    out = [tok]
    for i in range(n_gen - 1):
        pos = jnp.asarray(plen + i, jnp.int32)  # traced: no retrace per step
        tok, state = step(params, state, tok, pos, *(
            [enc_out] if enc_out is not None else []))
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    import dataclasses

    from repro.attention import AttentionSpec
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn:
        cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(args.attn))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    enc_out = None
    if cfg.encoder_layers > 0:
        from repro.models.encdec import encode
        frames = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            cfg.adtype())
        enc_out = encode(params, frames, cfg)

    t0 = time.monotonic()
    toks = generate(params, cfg, prompts, args.gen, enc_out=enc_out)
    dt = time.monotonic() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)  sample: "
          f"{np.asarray(toks[0][:16])}")


if __name__ == "__main__":
    main()
