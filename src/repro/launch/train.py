"""Production training driver.

Composes: model registry + sharding rules + optimizer + data pipeline +
checkpoint manager + fault tolerance. Runs on 1 CPU device (smoke/examples)
or any mesh; on TPU fleets launch one process per host (jax.distributed) —
the code is identical, only `--mesh` changes.

XLA flags we set on real TPU fleets for compute/comm overlap (recorded here;
they are no-ops on CPU):
    --xla_enable_async_collective_permute=true
    --xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_overlap_compute_collective_tc=true
    --xla_tpu_enable_data_parallel_all_reduce_opt=true

Usage (CPU example, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.attention import AttentionSpec
from repro.ckpt import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.ft import PreemptionHandler, StragglerMonitor
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import init_model
from repro.models.param import count_params
from repro.sharding import batch_spec, param_shardings


def build(args):
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    over = {}
    if args.attn:
        over["attn"] = AttentionSpec.parse(args.attn)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg


def _cp_mesh_context(args):
    """Context manager activating a (data, seq) mesh when --cp > 1.

    Under the active mesh, `attention()` plans seq mode
    (`repro.kernels.sharded`): each device scans its sequence shard with
    the Pallas kernels and exchanges one constant-size moment carry per
    boundary (forward prefix / backward suffix). --cp 1 is a no-op.
    """
    if args.cp <= 1:
        return contextlib.nullcontext()
    from repro.launch.mesh import make_test_mesh

    n_dev = len(jax.devices())
    if args.cp > n_dev or n_dev % args.cp:
        raise SystemExit(
            f"--cp {args.cp} must divide the device count ({n_dev})")
    if args.seq % args.cp:
        raise SystemExit(
            f"--seq {args.seq} must be divisible by --cp {args.cp}")
    mesh = make_test_mesh(shape=(n_dev // args.cp, args.cp),
                          axes=("data", "seq"))
    print(f"context parallelism: cp={args.cp} "
          f"mesh=(data={n_dev // args.cp}, seq={args.cp})", flush=True)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--attn", default=None,
                    help="attention operator (AttentionSpec.parse name, "
                         "e.g. softmax, fastmax2, fastmax2-kernel)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree: train under a "
                         "(data=n_dev/cp, seq=cp) mesh — fastmax attention "
                         "shards the sequence over 'seq' with one constant-"
                         "size moment exchange per shard boundary "
                         "(docs/context_parallel.md)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    with _cp_mesh_context(args):
        cfg = build(args)
        key = jax.random.PRNGKey(0)
        params, axes = init_model(key, cfg)
        n_params = count_params(params)
        print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
              f"attn={cfg.attn}", flush=True)

        opt_name, optimizer = pick_optimizer(cfg, n_params, lr=args.lr,
                                             total_steps=args.steps)
        opt_init, _ = optimizer
        opt_state = opt_init(params)
        train_step = jax.jit(make_train_step(cfg, optimizer),
                             donate_argnums=(0, 1))

        data = SyntheticLM(cfg.vocab_size, args.seq, seed=0)
        start_step = 0

        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            if args.resume and mgr.latest_step() is not None:
                (params, opt_state), start_step, _ = mgr.restore(
                    (params, opt_state))
                print(f"resumed from step {start_step}", flush=True)

        pre = PreemptionHandler()
        mon = StragglerMonitor()
        it = make_batch_iterator(data, args.batch, start_step=start_step)
        losses = []
        try:
            for step, batch in it:
                if step >= args.steps or pre.requested:
                    break
                mon.start_step()
                batch = jax.tree.map(jnp.asarray, batch)
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                dt = mon.end_step()
                losses.append(float(metrics["loss"]))
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss "
                          f"{float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['gnorm']):.3f} "
                          f"{dt*1e3:.0f}ms"
                          + (" [STRAGGLER]" if mon.straggling else ""),
                          flush=True)
                if mgr and step > 0 and step % args.ckpt_every == 0:
                    mgr.save(step, (params, opt_state), block=False)
        finally:
            it.close()
        if mgr:
            mgr.save(min(step, args.steps), (params, opt_state), block=True)
        print(f"final loss {np.mean(losses[-10:]):.4f} "
              f"(first10 {np.mean(losses[:10]):.4f}) "
              f"step_stats={mon.stats()}", flush=True)
        return params


if __name__ == "__main__":
    main()
