"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod = 16x16 = 256 chips (v5e); multi-pod = 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
    # newer JAX; older releases treat every axis as Auto already.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-style sharding tests (8 forced host devices)."""
    return _make_mesh(shape, axes)
