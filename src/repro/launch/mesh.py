"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod = 16x16 = 256 chips (v5e); multi-pod = 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
    # newer JAX; older releases treat every axis as Auto already.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False, cp: int = 1):
    """Single pod 16x16 ("data","model"); multi-pod prepends "pod"=2.

    `cp` > 1 trades the "model" axis for a "seq" (context-parallel) axis:
    the 256 chips per pod become (data=256/cp, seq=cp) — fastmax training
    then shards the SEQUENCE over "seq" (`repro.kernels.sharded` seq mode)
    with one constant-size moment exchange per boundary. CP×TP composition
    is deferred (ROADMAP), so cp is exclusive with the "model" axis.
    """
    if cp > 1:
        if 256 % cp:
            raise ValueError(f"cp={cp} must divide the 256 chips of a pod")
        shape = (2, 256 // cp, cp) if multi_pod else (256 // cp, cp)
        axes = ("pod", "data", "seq") if multi_pod else ("data", "seq")
        return _make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-style sharding tests (8 forced host devices)."""
    return _make_mesh(shape, axes)
