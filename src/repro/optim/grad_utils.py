"""Gradient utilities: global-norm clipping + compression w/ error feedback.

Compression note (distributed optimization): under pjit, the data-parallel
gradient reduction happens inside XLA's backward pass at the activations'
dtype — running the model with bf16 activations already halves all-reduce
bytes. `compress_decompress` adds an int8 (or bf16) error-feedback stage for
optimizer-state-side compression experiments: the quantization residual is
carried to the next step so the long-run update is unbiased.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["global_norm", "clip_by_global_norm", "compress_decompress"]


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def _quant(x, mode: str):
    if mode == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        amax = jnp.max(jnp.abs(x)) + 1e-12
        q = jnp.clip(jnp.round(x / amax * 127.0), -127, 127)
        return q * amax / 127.0
    raise ValueError(mode)


def compress_decompress(grads, error_state, mode: str = "int8"
                        ) -> Tuple[Any, Any]:
    """Error-feedback compression: g' = Q(g + e); e' = (g + e) - g'."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = _quant(corrected, mode)
        return q.astype(g.dtype), corrected - q

    out = jax.tree.map(one, grads, error_state)
    g_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    e_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_new, e_new
