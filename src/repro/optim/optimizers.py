"""AdamW and Lion with big-model state options (pure JAX).

State layouts (chosen per arch size, see launch/train.py):
  adamw:            m fp32, v fp32 (+ master fp32 if params are bf16)
  adamw_int8:       m int8 (per-block absmax) + eps-state, v fp32
  lion:             m bf16 — 2 bytes/param, for the 1T config
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw", "lion", "make_optimizer"]

_QBLOCK = 256


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any           # None for lion
    master: Any      # fp32 master params (None if params already fp32)


def _q8(x: jnp.ndarray):
    """Per-block absmax int8 quantization along the last axis."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _size(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: _size(shape)].reshape(shape)


def _wd_mask(path: tuple) -> bool:
    """No weight decay on norms/biases/scalars."""
    name = "/".join(str(getattr(p, "key", p)) for p in path)
    skip = ("scale", "bias", "bq", "bk", "bv", "bi", "bf", "bz", "bo",
            "dt_bias", "A_log", "D")
    return not any(name.endswith(s) for s in skip)


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray], *, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, int8_m: bool = False,
          master_fp32: bool = True):
    """Returns (init_fn, update_fn). update(grads, state, params)."""

    def init(params):
        def m_like(x):
            if int8_m:
                q, s = _q8(jnp.zeros(x.shape, jnp.float32))
                return {"q": q, "s": s}
            return jnp.zeros(x.shape, jnp.float32)

        m = jax.tree.map(m_like, params)
        v = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        master = None
        if master_fp32 and any(x.dtype != jnp.float32
                               for x in jax.tree.leaves(params)):
            master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), m, v, master)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        ref = state.master if state.master is not None else params

        def upd(path, g, m, v, p):
            g = g.astype(jnp.float32)
            if int8_m:
                m_f = _dq8(m["q"], m["s"], g.shape)
            else:
                m_f = m
            m_new = b1 * m_f + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m_new / b1c
            vhat = v_new / b2c
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay > 0 and _wd_mask(path):
                delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            if int8_m:
                q, s = _q8(m_new)
                m_out = {"q": q, "s": s}
            else:
                m_out = m_new
            return m_out, v_new, p_new

        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        paths = [p for p, _ in flat]
        treedef = jax.tree.structure(grads)
        g_l = [g for _, g in flat]
        m_l = jax.tree.leaves(state.m,
                              is_leaf=lambda x: isinstance(x, dict)
                              and "q" in x) if int8_m else jax.tree.leaves(
            state.m)
        v_l = jax.tree.leaves(state.v)
        p_l = jax.tree.leaves(ref)
        outs = [upd(path, g, m, v, p)
                for path, g, m, v, p in zip(paths, g_l, m_l, v_l, p_l)]
        m_new = jax.tree.unflatten(treedef, [o[0] for o in outs])
        v_new = jax.tree.unflatten(treedef, [o[1] for o in outs])
        p32 = jax.tree.unflatten(treedef, [o[2] for o in outs])
        if state.master is not None:
            new_params = jax.tree.map(
                lambda p_old, p_new_: p_new_.astype(p_old.dtype), params, p32)
            master = p32
        else:
            new_params = p32
            master = None
        return new_params, OptState(step, m_new, v_new, master)

    return init, update


def lion(lr: Callable[[jnp.ndarray], jnp.ndarray], *, b1=0.9, b2=0.99,
         weight_decay=0.1):
    """Lion: sign-momentum, 2-bytes/param state (bf16 momentum)."""

    def init(params):
        m = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.bfloat16), params)
        return OptState(jnp.zeros((), jnp.int32), m, None, None)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr(step)

        def upd(path, g, m, p):
            g = g.astype(jnp.float32)
            m_f = m.astype(jnp.float32)
            u = jnp.sign(b1 * m_f + (1 - b1) * g)
            if weight_decay > 0 and _wd_mask(path):
                u = u + weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            m_new = (b2 * m_f + (1 - b2) * g).astype(jnp.bfloat16)
            return m_new, p_new

        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        paths = [p for p, _ in flat]
        treedef = jax.tree.structure(grads)
        outs = [upd(path, g, m, p) for (path, g), m, p in
                zip(flat, jax.tree.leaves(state.m), jax.tree.leaves(params))]
        m_new = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_params = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, OptState(step, m_new, None, None)

    return init, update


def make_optimizer(name: str, lr_fn, **kw):
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adamw_int8":
        return adamw(lr_fn, int8_m=True, **kw)
    if name == "lion":
        return lion(lr_fn, **kw)
    raise ValueError(name)
