"""Optimizers + schedules + gradient utilities (pure JAX, no optax).

Features used at scale:
  * AdamW with optional fp32 master params (bf16 param trees) and optional
    int8-quantized first moment (per-block absmax scaling + error feedback)
    — halves optimizer HBM for the 405B/1T archs.
  * Lion (2 bytes/param state) for the largest configs.
  * Global-norm clipping, warmup+cosine schedule.
  * Gradient compression with error feedback (bf16/int8) — composes with
    data-parallel training; when activations are bf16 the backward psum is
    already bf16 (comm compression for free), this adds the error-feedback
    correction loop.
"""
from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw,
    lion,
    make_optimizer,
)
from repro.optim.schedules import warmup_cosine  # noqa: F401
from repro.optim.grad_utils import (  # noqa: F401
    clip_by_global_norm,
    global_norm,
    compress_decompress,
)
