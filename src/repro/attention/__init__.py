"""repro.attention — the unified attention-operator API.

One typed surface for every attention variant in the repo:

  * `AttentionSpec`   — frozen description of the operator (family, p, impl,
                        chunking, normalization, dropout, eps).
  * `attention(...)`  — the single dispatcher every model / serving /
                        benchmark path calls.
  * registry          — backends (`softmax`, `fastmax-oracle`,
                        `fastmax-rowwise`, `fastmax-chunked`,
                        `fastmax-kernel`) declare capabilities; capability
                        misses route explicitly (and are logged) instead of
                        falling back silently.
  * decode protocol   — `init_state` / `prefill` / `step` over the union
                        `AttnState` (KV cache for softmax, constant-size
                        moments for fastmax).

See docs/attention_api.md for the model and the migration table from the
retired `attn_backend`/`attn_impl` string pair.
"""
from repro.attention.api import attention, feature_shard_flag  # noqa: F401
from repro.attention.registry import (  # noqa: F401
    Backend,
    Capabilities,
    UnsupportedCapabilityError,
    get_backend,
    list_backends,
    register,
    resolve,
)
from repro.attention.spec import AttentionSpec  # noqa: F401
from repro.attention.state import (  # noqa: F401
    AttnState,
    KVCache,
    init_state,
    prefill,
    step,
)

__all__ = [
    "AttentionSpec",
    "attention",
    "feature_shard_flag",
    "Backend",
    "Capabilities",
    "UnsupportedCapabilityError",
    "get_backend",
    "list_backends",
    "register",
    "resolve",
    "AttnState",
    "KVCache",
    "init_state",
    "prefill",
    "step",
]
