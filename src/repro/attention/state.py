"""Unified decode-state protocol: `init_state` / `prefill` / `step`.

One streaming-inference surface for every backend family:

  softmax  -> `KVCache` (O(N) per sequence, the baseline's cost)
  fastmax  -> `Moments` (O(D^2 Dv) per kv head, INDEPENDENT of context —
              the paper's asymptotic punchline at inference)
  hybrid   -> BOTH legs: the fastmax moments plus a fixed-size rolling
              window `KVCache` of the last W = min(spec.window,
              chunk_size) tokens (the exact near-field band) — still
              O(1) in context length. W=0 carries moments only
              (bitwise fastmax).

`AttnState` is the union carried through the model's scan-over-layers;
at most one of (kv, moments) is populated — except the hybrid family,
which carries both. This protocol subsumes the seed's
`repro.core.decode_state` module and the per-backend decode branches that
lived in `repro.models.layers`.

Backends declaring `decode_kernel` (fastmax-kernel) run prefill and step
through the Pallas kernels on the SAME `Moments` carry: prefill's final
moments are emitted by the forward kernel itself (no recompute pass) and
each step is the fused update+combine decode kernel. Off-TPU the protocol
falls back to the jnp moment step with one logged routing line
(REPRO_DECODE_KERNEL=1 forces the kernel in interpret mode — tests/CI;
=0 disables it everywhere).

Under a multi-device mesh the kernels launch shard_map-wrapped
(`repro.kernels.sharded`) in heads or feature (Dv) mode — since the
Dv-blocked backward landed, that covers TRAINING at every TP degree too
(`attention/backends.py`), so the serve protocol here and the trainable
path commit one and the same moment layout between steps
(`decode_state_shardings`).
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.attention.api import feature_shard_flag
from repro.attention.registry import _log_once, resolve
from repro.attention.spec import AttentionSpec
from repro.core.decode_state import init_fastmax_state
from repro.core.hybrid import _hybrid_scan, effective_window, roll_window
from repro.core.ref import poly_kernel
from repro.core.fastmax import (
    Moments,
    _causal_scan,
    _constrain_moments_j,
    combine_with_queries,
    compute_moments,
    normalize_qk,
)
from repro.core.softmax import softmax_attention

__all__ = ["KVCache", "AttnState", "init_state", "prefill", "step",
           "use_decode_kernel"]


def use_decode_kernel(spec: AttentionSpec) -> bool:
    """True when this spec's decode should run the fused Pallas kernels.

    Requires a backend with the `decode_kernel` capability (fastmax-kernel).
    On TPU that routes decode to the kernel; elsewhere the jnp moment step
    is the fallback (logged once). REPRO_DECODE_KERNEL=1 forces the kernel
    (interpret mode off-TPU); =0 disables it even on TPU.

    Under a multi-device mesh the kernels run shard_map-wrapped
    (`repro.kernels.sharded`): heads mode when kv heads divide the 'model'
    axis, feature (Dv) mode otherwise — the per-call plan is picked in
    `_kernel_plan`; only dims that fit NEITHER mode fall back to the jnp
    feature-TP moment step (logged).
    """
    if spec.family == "softmax":
        return False
    backend = resolve(spec, causal=True)
    if not backend.caps.decode_kernel:
        return False
    env = os.environ.get("REPRO_DECODE_KERNEL", "auto").lower()
    if env in ("0", "off", "never"):
        _log_once(f"decode: {backend.name} kernel disabled "
                  f"(REPRO_DECODE_KERNEL={env})")
        return False
    if env in ("1", "force", "always"):
        _log_once(f"decode: {backend.name} native-state kernel (forced; "
                  f"interpret off-TPU)")
        return True
    if jax.default_backend() == "tpu":
        _log_once(f"decode: {backend.name} native-state kernel")
        return True
    _log_once(
        f"decode: {backend.name} targets tpu; platform="
        f"{jax.default_backend()} -> jnp moment step fallback")
    return False


def _kernel_plan(q, k, v):
    """(mesh, plan) for a kernel launch under the active mesh.

    mesh None -> single-device: plain kernel call. mesh set, plan None ->
    the mesh tensor-parallelizes but neither kv heads nor Dv divide the
    'model' axis: route to the jnp feature-TP moment step (logged by the
    caller). Otherwise the kernel runs shard_map-wrapped per the plan.
    """
    from repro.kernels.sharded import nontrivial_mesh, plan_kernel_sharding

    mesh = nontrivial_mesh()
    if mesh is None:
        return None, None
    plan = plan_kernel_sharding(mesh, batch=q.shape[0], hq=q.shape[1],
                                hkv=k.shape[1], dv=v.shape[-1])
    if plan is not None:
        _log_once(f"decode: fastmax kernel {plan.describe()}")
    return mesh, plan


class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, Hkv, Nmax, D]
    v: jnp.ndarray       # [B, Hkv, Nmax, Dv]
    length: jnp.ndarray  # [] int32 (shared), or [B] int32 (slot-indexed:
    #                      per-sequence write cursors — repro.serve pools)
    mask: jnp.ndarray    # [B, Hkv, Nmax] validity (1=real token) — lets a
    #                      masked prefill stay masked through every step


class AttnState(NamedTuple):
    """Union decode state. softmax uses `kv`, fastmax uses `moments`;
    hybrid uses both (`kv` is the rolling near-field window, W slots)."""
    kv: Optional[KVCache]
    moments: Optional[Moments]


def _window_slots(spec: AttentionSpec) -> int:
    """Rolling-window size the hybrid decode state carries (0 = none)."""
    if spec.family != "hybrid":
        return 0
    return effective_window(spec.window, spec.resolved().chunk_size)


def _check_state(state: AttnState, spec: AttentionSpec) -> None:
    if spec.family == "hybrid":
        if state.moments is None or (_window_slots(spec) > 0
                                     and state.kv is None):
            raise ValueError(
                f"AttnState lacks the moments/window legs required by "
                f"{spec} — the state was initialized for a different "
                f"attention family or window")
        return
    leg = "kv" if spec.family == "softmax" else "moments"
    if getattr(state, leg) is None:
        raise ValueError(
            f"AttnState carries no {leg!r} but spec is {spec} — the state "
            f"was initialized for a different attention family")


def init_state(spec: AttentionSpec, *, batch: int, n_kv_heads: int,
               q_head_dim: int, v_head_dim: int, max_len: int,
               dtype=jnp.float32) -> AttnState:
    """Fresh per-layer decode state for `batch` sequences of <= max_len."""
    backend = resolve(spec, causal=True)
    if not backend.caps.decode:
        raise ValueError(
            f"backend {backend.name!r} has no decode path; use a spec whose "
            f"backend declares decode=True")
    if spec.family == "softmax":
        kv = KVCache(
            k=jnp.zeros((batch, n_kv_heads, max_len, q_head_dim), dtype),
            v=jnp.zeros((batch, n_kv_heads, max_len, v_head_dim), dtype),
            length=jnp.zeros((), jnp.int32),
            mask=jnp.ones((batch, n_kv_heads, max_len), jnp.float32),
        )
        return AttnState(kv=kv, moments=None)
    mom = init_fastmax_state(batch, n_kv_heads, q_head_dim, v_head_dim,
                             p=spec.p, dtype=jnp.float32)
    w = _window_slots(spec)
    if w > 0:
        # hybrid near-field window: the last <=W tokens, right-aligned
        # (row W-1 most recent); `length` counts TOTAL tokens folded so
        # far (moments semantics), not a write cursor — the shift-append
        # is position-independent. mask starts all-zero (window empty).
        kv = KVCache(
            k=jnp.zeros((batch, n_kv_heads, w, q_head_dim), dtype),
            v=jnp.zeros((batch, n_kv_heads, w, v_head_dim), dtype),
            length=jnp.zeros((), jnp.int32),
            mask=jnp.zeros((batch, n_kv_heads, w), jnp.float32),
        )
        return AttnState(kv=kv, moments=mom)
    return AttnState(kv=None, moments=mom)


def prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            spec: AttentionSpec, *, state: AttnState,
            kv_mask: Optional[jnp.ndarray] = None,
            offset: Optional[jnp.ndarray] = None):
    """Causal prefill of a prompt: returns (outputs, primed AttnState).

    softmax: fills the KV cache. fastmax: one chunked causal scan produces
    BOTH the outputs and the final moments (the seed recomputed moments in a
    second pass).

    `offset` (traced scalar) makes the prefill RESUMABLE: the incoming
    `state` is treated as the state of tokens [0, offset) and this call
    appends tokens [offset, offset + n) — the chunked-prefill primitive of
    the serving engine (`repro.serve`). softmax writes the chunk at
    `offset` in the cache and attends over the valid prefix via `q_offset`;
    fastmax seeds the causal scan with the carried moments. With
    `offset=None` the legacy whole-prompt behavior (and its exact HLO) is
    preserved. `kv_mask` may be [B, N] or [B, Hkv, N]; with a vector
    `length` lane (slot pools) the new lengths are per-sequence
    offset + (valid tokens in this chunk).
    """
    b, n = q.shape[0], q.shape[2]
    hkv = k.shape[1]
    _check_state(state, spec)
    if kv_mask is not None and kv_mask.ndim == 2:
        kv_mask = jnp.broadcast_to(kv_mask[:, None], (b, hkv, n))
    if spec.family == "softmax":
        from repro.sharding.rules import constrain_kv_cache
        kv = state.kv
        off = jnp.asarray(0 if offset is None else offset, jnp.int32)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kv.k, k.astype(kv.k.dtype), off, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            kv.v, v.astype(kv.v.dtype), off, axis=2)
        kc = constrain_kv_cache(kc)
        vc = constrain_kv_cache(vc)
        mc = kv.mask
        if kv_mask is not None:
            # persist prompt padding so every later step keeps it masked
            mc = jax.lax.dynamic_update_slice_in_dim(
                mc, kv_mask.astype(mc.dtype), off, axis=2)
        if offset is None:
            o = softmax_attention(q, k, v, causal=True, kv_mask=kv_mask)
        else:
            # resume: attend over the whole cache — rows < offset are the
            # carried prefix (validity from the mask lane), rows >= offset+n
            # are excluded causally via q_offset
            o = softmax_attention(q, kc, vc, causal=True, q_offset=off,
                                  kv_mask=mc)
        if kv.length.ndim == 0:
            # legacy shared cursor: padding rows stay masked via the mask
            # lane but still occupy cache rows (decode appends at n)
            new_len = off + jnp.asarray(n, jnp.int32)
        else:
            # slot pools: per-sequence cursors — decode appends right after
            # each sequence's last VALID token
            nvalid = (jnp.full((b,), n, jnp.int32) if kv_mask is None else
                      jnp.sum(kv_mask[:, 0, :] > 0, axis=-1).astype(jnp.int32))
            new_len = off + jnp.broadcast_to(nvalid, kv.length.shape)
        return o, AttnState(kv=KVCache(kc, vc, new_len, mc), moments=None)
    spec_r = spec.resolved()
    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    w_slots = _window_slots(spec)
    if w_slots > 0:
        # hybrid: one jnp scan yields outputs AND the final moments; the
        # near-field window is recompacted to the last <=W valid tokens
        # (normalized keys — band scores are q̂·k̂). With `offset` the
        # carried window seeds the scan's previous-chunk buffer and the
        # carried moments seed the far field. W=0 hybrid falls through to
        # the fastmax moment paths below (bitwise identical).
        fs = feature_shard_flag(hkv)
        kv = state.kv
        if offset is not None:
            _log_once("prefill: hybrid resumable (offset) chunk via the "
                      "jnp hybrid scan")
            init, init_win = state.moments, (kv.k, kv.v, kv.mask)
        else:
            init, init_win = None, None
        o, final = _hybrid_scan(
            qh, kh, v, p=spec.p, window=spec_r.window,
            chunk_size=spec_r.chunk_size, kv_mask=kv_mask,
            denom_eps=spec.denom_eps, feature_shard=fs,
            init=init, init_win=init_win)
        m = (jnp.ones((b, hkv, n), jnp.float32) if kv_mask is None
             else kv_mask.astype(jnp.float32))
        nk, nv, nm = roll_window(
            kv.k if offset is not None else None,
            kv.v if offset is not None else None,
            kv.mask if offset is not None else None,
            kh, v, m, w_slots)
        off = jnp.asarray(0 if offset is None else offset, jnp.int32)
        if kv.length.ndim == 0:
            new_len = off + jnp.asarray(n, jnp.int32)
        else:
            nvalid = (jnp.full((b,), n, jnp.int32) if kv_mask is None else
                      jnp.sum(kv_mask[:, 0, :] > 0,
                              axis=-1).astype(jnp.int32))
            new_len = off + jnp.broadcast_to(nvalid, kv.length.shape)
        nkv = KVCache(nk.astype(kv.k.dtype), nv.astype(kv.v.dtype),
                      new_len, nm)
        return o.astype(q.dtype), AttnState(kv=nkv,
                                            moments=Moments(*final))
    if offset is not None:
        # resumable chunked prefill: seed the jnp scan with the carried
        # moments (the Pallas prefill kernels take no initial carry; decode
        # steps after the handoff still route to the kernels)
        _log_once("prefill: resumable (offset) chunk -> jnp moment scan")
        fs = feature_shard_flag(k.shape[1])
        o, final = _causal_scan(
            qh, kh, v, p=spec.p, chunk_size=spec_r.chunk_size,
            kv_mask=kv_mask, denom_eps=spec.denom_eps, feature_shard=fs,
            init=state.moments)
        return o.astype(q.dtype), AttnState(kv=None, moments=Moments(*final))
    if use_decode_kernel(spec):
        # one kernel launch yields outputs AND the final carry — the
        # prefill→decode handoff without recomputing moments
        from repro.kernels import ops as kernel_ops
        mesh, plan = _kernel_plan(q, k, v)
        if plan is not None:
            from repro.kernels.sharded import fastmax_prefill_sharded
            o, state = fastmax_prefill_sharded(
                qh, kh, v, p=spec.p, chunk_size=spec_r.chunk_size,
                denom_eps=spec.denom_eps, kv_mask=kv_mask, plan=plan)
            return o.astype(q.dtype), AttnState(kv=None,
                                                moments=Moments(*state))
        if mesh is None:
            o, state = kernel_ops.fastmax_prefill_kernel(
                qh, kh, v, p=spec.p, chunk_size=spec_r.chunk_size,
                denom_eps=spec.denom_eps, kv_mask=kv_mask)
            return o.astype(q.dtype), AttnState(kv=None,
                                                moments=Moments(*state))
        _log_once(
            "decode: fastmax kernel unpartitionable over 'model' "
            "(kv heads and Dv both indivisible) -> jnp feature-TP scan")
    # the jnp chunked scan is sharding-aware: under feature-TP the stacked
    # chunks are pinned and the carry constrained (see _causal_scan)
    fs = feature_shard_flag(k.shape[1])
    o, final = _causal_scan(
        qh, kh, v, p=spec.p, chunk_size=spec_r.chunk_size, kv_mask=kv_mask,
        denom_eps=spec.denom_eps, feature_shard=fs)
    return o.astype(q.dtype), AttnState(kv=None, moments=final)


def step(state: AttnState, q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         spec: AttentionSpec):
    """One-token decode. q:[B,Hq,1,D], k/v:[B,Hkv,1,*].

    softmax: append to the cache, attend over the valid prefix.
    fastmax: fold (k, v) into the moments, contract with q —
    O(D^p Dv) per head per token, independent of context length.
    Returns (o [B,Hq,1,Dv], new AttnState).
    """
    _check_state(state, spec)
    if spec.family == "softmax":
        from repro.sharding.rules import constrain_kv_cache, model_axis_size
        kv = state.kv
        if kv.length.ndim == 0:
            # legacy shared cursor: one dynamic_update_slice for the batch
            kc = jax.lax.dynamic_update_slice_in_dim(
                kv.k, k.astype(kv.k.dtype), kv.length, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(
                kv.v, v.astype(kv.v.dtype), kv.length, axis=2)
            mc = kv.mask
        else:
            # slot-indexed pool: per-sequence write cursors (each slot may
            # sit at a different context length) — scatter one row per
            # sequence, and mark the written row valid in the mask lane
            # (chunked prefill may have left a padding marker there)
            bidx = jnp.arange(kv.k.shape[0])
            kc = kv.k.at[bidx, :, kv.length].set(
                k[:, :, 0, :].astype(kv.k.dtype))
            vc = kv.v.at[bidx, :, kv.length].set(
                v[:, :, 0, :].astype(kv.v.dtype))
            mc = kv.mask.at[bidx, :, kv.length].set(1.0)
        # pin the freshly-updated cache to its committed inter-step layout
        # (kv_cache_spec: heads over 'model' when divisible, else the
        # sequence dim) — without this the partitioner resolves the
        # head-sharded-consumer vs head_dim-sharded-cache conflict by
        # fully rematerializing cache-sized tensors every step (the 3
        # SOFTMAX 32k-decode warnings, ROADMAP)
        kc = constrain_kv_cache(kc)
        vc = constrain_kv_cache(vc)
        nmax = kc.shape[2]
        length_b = kv.length if kv.length.ndim else kv.length[None]
        mask = (jnp.arange(nmax)[None, None, :]
                <= length_b[:, None, None]).astype(jnp.float32) * mc
        mask = constrain_kv_cache(mask)
        tp = model_axis_size()
        if tp > 1 and k.shape[1] % tp != 0:
            # sequence-sharded cache: queries must be model-replicated so
            # the softmax over the sharded timeline partitions as partial
            # max/sum reductions instead of resharding the cache
            from repro.sharding.rules import replicate
            q = replicate(q, batch_dim=0)
        o = softmax_attention(q, kc, vc, causal=False, kv_mask=mask)
        return o, AttnState(kv=KVCache(kc, vc, kv.length + 1, mc),
                            moments=None)

    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    hkv, hq = k.shape[1], q.shape[1]
    if use_decode_kernel(spec):
        from repro.kernels import ops as kernel_ops
        mesh, plan = _kernel_plan(q, k, v)
        if plan is not None:
            from repro.kernels.sharded import fastmax_decode_sharded
            o, new_state = fastmax_decode_sharded(
                qh, kh, v, tuple(state.moments), p=spec.p,
                denom_eps=spec.denom_eps, plan=plan)
            return (o.astype(q.dtype),
                    AttnState(kv=None, moments=Moments(*new_state)))
        if mesh is None:
            o, new_state = kernel_ops.fastmax_decode(
                qh, kh, v, state.moments, p=spec.p, denom_eps=spec.denom_eps)
            return (o.astype(q.dtype),
                    AttnState(kv=None, moments=Moments(*new_state)))
        _log_once(
            "decode: fastmax kernel unpartitionable over 'model' "
            "(kv heads and Dv both indivisible) -> jnp feature-TP step")
    # jnp moment step. Under tensor parallelism the moments are sharded on
    # their feature (Dv / trailing-D) dims while q arrives head-sharded —
    # constrain the delta, the running state, and the combine to consistent
    # feature-TP so XLA never rematerializes a moment-sized tensor
    # (ROADMAP serve-path item; see combine_with_queries(feature_shard=)).
    fs = feature_shard_flag(hkv)
    if fs:
        # the new token's k/v are tiny — pin them model-replicated (keeping
        # DP on batch) so every device builds ITS OWN feature slice of the
        # moment delta locally; without this the delta (full moment size!)
        # is produced head-sharded and resharded over the ICI every step
        from repro.sharding.rules import replicate
        kh = replicate(kh, batch_dim=0)
        v = replicate(v, batch_dim=0)
    delta = compute_moments(kh, v, p=spec.p)
    if fs:
        delta = _constrain_moments_j(delta)
    new_mom = state.moments + delta
    if fs:
        new_mom = _constrain_moments_j(new_mom)
    # fold the query group into the token axis (no broadcast of the state)
    qg = qh.reshape(q.shape[0], hkv, hq // hkv, q.shape[-1])
    num, den = combine_with_queries(qg, new_mom, p=spec.p, feature_shard=fs)
    new_kv = None
    w_slots = _window_slots(spec)
    if w_slots > 0:
        # hybrid near field: the moments above already weighted every
        # causal token by f_p; add the (exp - f_p) correction for the
        # in-band ones — the token itself (distance 0) and window rows
        # 1..W-1 (row r holds the token at distance W-r, so row 0 sits
        # at distance W, just out of band)
        kv = state.kv
        acc = jnp.promote_types(qg.dtype, jnp.float32)
        qf = qg.astype(acc)
        s0 = jnp.einsum("bhgd,bhtd->bhg", qf, kh.astype(acc))
        c0 = jnp.exp(s0) - poly_kernel(s0, spec.p)
        num = num + c0[..., None] * v[:, :, 0].astype(num.dtype)[:, :, None]
        den = den + c0
        sw = jnp.einsum("bhgd,bhwd->bhgw", qf, kv.k.astype(acc))
        cw = jnp.exp(sw) - poly_kernel(sw, spec.p)
        in_band = (jnp.arange(w_slots) >= 1).astype(acc)
        cw = cw * (in_band[None, None, None, :] * kv.mask[:, :, None, :])
        num = num + jnp.einsum("bhgw,bhwj->bhgj", cw,
                               kv.v.astype(acc)).astype(num.dtype)
        den = den + jnp.sum(cw, axis=-1)
        # shift-append the new token at row W-1 (most recent)
        nk = jnp.concatenate([kv.k[:, :, 1:], kh.astype(kv.k.dtype)],
                             axis=2)
        nv = jnp.concatenate([kv.v[:, :, 1:], v.astype(kv.v.dtype)],
                             axis=2)
        nm = jnp.concatenate([kv.mask[:, :, 1:],
                              jnp.ones_like(kv.mask[:, :, :1])], axis=2)
        new_kv = KVCache(nk, nv, kv.length + 1, nm)
    o = num / (den + spec.denom_eps)[..., None]
    o = o.reshape(q.shape[0], hq, 1, -1).astype(q.dtype)
    return o, AttnState(kv=new_kv, moments=new_mom)
