"""`AttentionSpec` — the one typed description of an attention operator.

Replaces the seed's stringly-typed `attn_backend`/`attn_impl` pair, the
13-kwarg `fastmax_attention()` surface, and the unused `FastmaxConfig`
NamedTuple. A spec names a *family* (softmax | fastmax | hybrid), the
polynomial order `p` for fastmax, and the *impl* schedule within the
family; the registry (`repro.attention.registry`) maps
`spec.backend_name` to a registered backend and routes around missing
capabilities.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["AttentionSpec", "FAMILIES", "IMPLS", "HYBRID_IMPLS"]

FAMILIES = ("softmax", "fastmax", "hybrid")
# impl schedules within the fastmax family (softmax has a single impl)
IMPLS = ("oracle", "rowwise", "chunked", "kernel")
# the hybrid family has no rowwise/oracle schedule (its jnp oracle is the
# composed reference in repro.core.hybrid, exercised by tests directly)
HYBRID_IMPLS = ("chunked", "kernel")


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Static, hashable configuration of one attention operator.

    Fields:
      family:       "softmax" (paper baseline), "fastmax" (the paper's
                    factorizable polynomial attention), or "hybrid"
                    (FMMformer-style near/far field: exact softmax over a
                    width-`window` causal band + fastmax moments off-band,
                    combined in one normalizer).
      p:            polynomial order of the fastmax kernel (paper: 1 or 2).
      impl:         schedule within the family — "oracle" (O(N^2) reference),
                    "rowwise" (paper's per-row prefix moments), "chunked"
                    (TPU-native chunked prefix scan), "kernel" (Pallas).
                    hybrid supports "chunked" and "kernel".
      chunk_size:   chunk length for the scan schedules; None inherits the
                    caller's default (ModelConfig.chunk_size / 128).
      window:       hybrid only — width of the exact near-field band,
                    *including* the diagonal (a token always sees itself
                    exactly). The effective band is clamped to one chunk:
                    w_eff = min(window, chunk_size); widening the band past
                    the chunk length requires raising chunk_size. window=0
                    degenerates bitwise to fastmax; w_eff >= N is exact
                    softmax over normalized q/k.
      normalize:    statistical q/k normalization (paper Eqs. 5-6).
      denom_eps:    guard for p=1's sign-indefinite denominator.
      custom_grad:  paper §2.5 memory-reduced backward (chunked/kernel).
      dropout_rate/dropout_mode: the paper's Fig. 2 dropout variants
                    ("quadratic" | "1d"); active only when an rng is passed
                    to `attention(...)`.
    """

    family: str = "fastmax"
    p: int = 2
    impl: str = "chunked"
    chunk_size: Optional[int] = None
    window: int = 64
    normalize: bool = True
    denom_eps: float = 1e-6
    custom_grad: bool = True
    dropout_rate: float = 0.0
    dropout_mode: str = "quadratic"

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"unknown attention family {self.family!r}; "
                f"expected one of {FAMILIES}")
        if self.family == "fastmax":
            if self.impl not in IMPLS:
                raise ValueError(
                    f"unknown fastmax impl {self.impl!r}; "
                    f"expected one of {IMPLS}")
            if self.p not in (1, 2):
                raise ValueError(f"fastmax p must be 1 or 2, got {self.p}")
        if self.family == "hybrid":
            if self.impl not in HYBRID_IMPLS:
                raise ValueError(
                    f"unknown hybrid impl {self.impl!r}; "
                    f"expected one of {HYBRID_IMPLS}")
            if self.p not in (1, 2):
                raise ValueError(f"hybrid p must be 1 or 2, got {self.p}")
            if self.window < 0:
                raise ValueError(
                    f"hybrid window must be >= 0, got {self.window}")
        if self.dropout_mode not in ("quadratic", "1d", "none"):
            raise ValueError(f"unknown dropout_mode {self.dropout_mode!r}")

    # -- registry keys ------------------------------------------------------

    @property
    def backend_name(self) -> str:
        """Registry name of the backend this spec requests."""
        if self.family == "softmax":
            return "softmax"
        return f"{self.family}-{self.impl}"

    @property
    def legacy_name(self) -> str:
        """The retired `attn_backend` string ("softmax"/"fastmax1"/
        "fastmax2") — kept for result-JSON/back-compat labels only."""
        if self.family == "softmax":
            return "softmax"
        return f"{self.family}{self.p}"

    def __str__(self) -> str:
        if self.family == "softmax":
            return "softmax"
        if self.family == "hybrid":
            return f"hybrid{self.p}/{self.impl}/w{self.window}"
        return f"fastmax{self.p}/{self.impl}"

    # -- construction helpers ----------------------------------------------

    @classmethod
    def parse(cls, name: Optional[str], **overrides) -> "AttentionSpec":
        """Parse a CLI-style operator name into a spec.

        Accepted: "softmax", "fastmax" (p=2), "fastmax1", "fastmax2",
        "hybrid"/"hybrid1"/"hybrid2", registry names ("fastmax-chunked",
        "hybrid-kernel", ...), and "<family>[p][-impl]" combinations such
        as "fastmax1-kernel" or "hybrid2-kernel". None -> default spec.
        """
        if name is None:
            return cls(**overrides)
        base, _, impl = name.partition("-")
        kw = dict(overrides)
        if impl:
            kw.setdefault("impl", impl)
        if base == "softmax":
            if impl:
                raise ValueError(
                    f"softmax has no impl variants; got {name!r}")
            return cls(family="softmax", **{k: v for k, v in kw.items()
                                            if k != "impl"})
        if base in ("fastmax", "fastmax1", "fastmax2"):
            if base != "fastmax":
                kw.setdefault("p", int(base[-1]))
            return cls(family="fastmax", **kw)
        if base in ("hybrid", "hybrid1", "hybrid2"):
            if base != "hybrid":
                kw.setdefault("p", int(base[-1]))
            return cls(family="hybrid", **kw)
        raise ValueError(f"cannot parse attention operator name {name!r}")

    def with_flags(self, backend: Optional[str] = None,
                   impl: Optional[str] = None) -> "AttentionSpec":
        """Deprecation shim: apply a legacy `attn_backend`/`attn_impl`
        string pair on top of this spec."""
        spec = self
        if backend:
            spec = AttentionSpec.parse(
                backend,
                **{f.name: getattr(spec, f.name)
                   for f in dataclasses.fields(spec)
                   if f.name not in ("family", "p")})
        if impl:
            spec = dataclasses.replace(spec, impl=impl)
        return spec

    def resolved(self, default_chunk_size: int = 128) -> "AttentionSpec":
        """Fill inherited fields (chunk_size) for dispatch."""
        if self.chunk_size is not None:
            return self
        return dataclasses.replace(self, chunk_size=default_chunk_size)
