"""Built-in attention backends.

Importing this module registers:

  softmax          — paper baseline (Eqs. 1-4), O(N^2), KV-cache decode.
  fastmax-oracle   — O(N^2) fastmax reference (tests/validation only).
  fastmax-rowwise  — the paper's own schedule; the only backend with the
                     Fig. 2 factorized dropout variants.
  fastmax-chunked  — TPU-native chunked prefix scan (production default);
                     exact kv masking, feature-TP, §2.5 custom backward.
  fastmax-kernel   — Pallas TPU kernels; interprets off-TPU.
  hybrid-chunked   — FMMformer-style near/far field: exact softmax over a
                     width-`spec.window` causal band + fastmax moments
                     off-band, one normalizer (repro.core.hybrid). Causal
                     only; exact kv masking, feature-TP, §2.5+band custom
                     backward. window=0 degenerates bitwise to fastmax.
  hybrid-kernel    — fused Pallas launch for the hybrid forward
                     (kernels/hybrid_causal.py) with the jnp band-extended
                     reverse scan as backward; interprets off-TPU.

All fns share one signature:
  fn(q, k, v, spec, *, causal, kv_mask, rng, feature_shard) -> o
with q:[B,Hq,N,D], k/v:[B,Hkv,M,*], Hq % Hkv == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.attention.registry import Backend, Capabilities, register
from repro.attention.spec import AttentionSpec

__all__ = []  # import for side effect (registration)


def _softmax_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
                feature_shard):
    from repro.core.softmax import softmax_attention

    del spec, rng, feature_shard
    # softmax_attention is natively GQA-aware (groups q per kv head); no
    # Hq-broadcast copies of k/v. kv_mask is per-kv-head: [B, Hkv|1, M].
    if kv_mask is not None and kv_mask.shape[1] not in (1, k.shape[1]):
        raise ValueError(
            f"kv_mask heads {kv_mask.shape[1]} must be 1 or Hkv="
            f"{k.shape[1]}")
    return softmax_attention(q, k, v, causal=causal, kv_mask=kv_mask)


def _oracle_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
               feature_shard):
    from repro.core.fastmax import _group_queries, _ungroup
    from repro.core.ref import fastmax_attention_ref

    del kv_mask, rng, feature_shard
    hkv = k.shape[1]
    qg = _group_queries(q, hkv)
    o = jax.vmap(
        lambda qq: fastmax_attention_ref(
            qq, k, v, p=spec.p, causal=causal, normalize=spec.normalize,
            denom_eps=spec.denom_eps),
        in_axes=2, out_axes=2,
    )(qg)
    return _ungroup(o)


def _rowwise_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
                feature_shard):
    from repro.core.fastmax import fastmax_rowwise

    del kv_mask, feature_shard
    if not spec.normalize:
        raise ValueError("fastmax-rowwise always normalizes (paper schedule)")
    return fastmax_rowwise(
        q, k, v, p=spec.p, causal=causal, denom_eps=spec.denom_eps,
        dropout_rate=spec.dropout_rate if rng is not None else 0.0,
        dropout_mode=spec.dropout_mode, dropout_rng=rng)


def _chunked_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
                feature_shard):
    from repro.core.fastmax import (fastmax_causal_chunked, fastmax_noncausal,
                                    normalize_qk)

    del rng
    spec = spec.resolved()
    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    if causal:
        return fastmax_causal_chunked(
            qh, kh, v, p=spec.p, chunk_size=spec.chunk_size, kv_mask=kv_mask,
            denom_eps=spec.denom_eps, custom_grad=spec.custom_grad,
            feature_shard=feature_shard)
    return fastmax_noncausal(
        qh, kh, v, p=spec.p, kv_mask=kv_mask, denom_eps=spec.denom_eps,
        chunk_size=max(spec.chunk_size, 512), feature_shard=feature_shard)


def _kernel_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
               feature_shard):
    from repro.attention.registry import _log_once
    from repro.core.fastmax import normalize_qk
    from repro.kernels import ops as kernel_ops
    from repro.kernels.sharded import nontrivial_mesh, plan_kernel_sharding

    del kv_mask, rng
    spec = spec.resolved()
    mesh = nontrivial_mesh()
    if mesh is not None:
        from repro.kernels.ops import use_pallas_bwd
        plan = plan_kernel_sharding(
            mesh, batch=q.shape[0], hq=q.shape[1], hkv=k.shape[1],
            dv=v.shape[-1],
            # seq mode (context parallelism) is causal-training-shaped
            # only: N == M (self-attention over the full sequence)
            seq_len=q.shape[2] if causal and q.shape[2] == k.shape[2]
            else None)
        if plan is not None and (plan.mode == "heads"
                                 or (causal and (plan.mode == "seq"
                                                 or use_pallas_bwd()))
                                 or (not causal
                                     and plan.mode == "feature")):
            # heads mode: fwd AND the fused Pallas bwd run shard-local per
            # (batch, kv-head) — autodiff of the shard_map applies the
            # custom_vjp per shard. feature mode (causal): the Dv-blocked
            # kernels run per value-feature shard — forward collective-
            # free, backward with one psum of the partial dq/dk per
            # launch; REPRO_FASTMAX_BWD=jnp restores the sharding-aware
            # chunked scan (the equivalence oracle). feature mode
            # (noncausal): shard_map wrap of the two-phase noncausal
            # kernel — the global moments are Dv-decomposable and its den
            # comes from replicated k, so each shard's output slice is
            # exact and collective-free; training autodiffs the wrap (the
            # op pairs a jnp moment backward, shard_map psums dq/dk). seq
            # mode (context parallelism): each device scans its sequence
            # shard, one constant-size moment exchange per direction —
            # both backward backends support the seeded carry, so it
            # routes either way.
            from repro.kernels.sharded import fastmax_sharded
            _log_once(f"attention: fastmax-kernel {plan.describe()}")
            qh = normalize_qk(q) if spec.normalize else q
            kh = normalize_qk(k) if spec.normalize else k
            return fastmax_sharded(qh, kh, v, p=spec.p, causal=causal,
                                   chunk_size=spec.chunk_size,
                                   denom_eps=spec.denom_eps, plan=plan)
        # unpartitionable mesh (kv heads AND Dv indivisible) or the jnp
        # backward oracle: sharding-aware chunked scan
        _log_once(
            "attention: fastmax-kernel under 'model' mesh without a "
            "kernel-shardable plan for this call (unpartitionable dims "
            "or REPRO_FASTMAX_BWD=jnp) -> chunked scan (feature-TP)")
        return _chunked_fn(q, k, v, spec, causal=causal, kv_mask=None,
                           rng=None, feature_shard=feature_shard)
    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    return kernel_ops.fastmax(qh, kh, v, p=spec.p, causal=causal,
                              chunk_size=spec.chunk_size,
                              denom_eps=spec.denom_eps)


def _hybrid_chunked_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
                       feature_shard):
    from repro.core.fastmax import normalize_qk
    from repro.core.hybrid import hybrid_causal_chunked

    del rng
    if not causal:
        raise ValueError("hybrid attention is causal-only")
    spec = spec.resolved()
    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    # w_eff=0 delegates (inside hybrid_causal_chunked) to the fastmax
    # chunked scan with identical arguments — bitwise fastmax parity
    return hybrid_causal_chunked(
        qh, kh, v, p=spec.p, window=spec.window, chunk_size=spec.chunk_size,
        kv_mask=kv_mask, denom_eps=spec.denom_eps,
        custom_grad=spec.custom_grad, feature_shard=feature_shard)


def _hybrid_kernel_fn(q, k, v, spec: AttentionSpec, *, causal, kv_mask, rng,
                      feature_shard):
    from repro.attention.registry import _log_once
    from repro.core.fastmax import normalize_qk
    from repro.kernels import ops as kernel_ops
    from repro.kernels.sharded import nontrivial_mesh, plan_kernel_sharding

    del kv_mask, rng
    if not causal:
        raise ValueError("hybrid attention is causal-only")
    spec = spec.resolved()
    mesh = nontrivial_mesh()
    if mesh is not None:
        # heads mode: the fused hybrid launch runs shard-local per
        # (batch, kv-head). feature mode: the Dv-blocked forward emits its
        # carry per value-feature shard and the band-extended jnp reverse
        # scan closes the backward with one psum of partial dq/dk — the
        # band denominator is Dv-independent (it comes from replicated
        # q/k), so each shard's output slice is exact. No seq mode: the
        # hybrid family is not context-parallel-wired yet.
        plan = plan_kernel_sharding(
            mesh, batch=q.shape[0], hq=q.shape[1], hkv=k.shape[1],
            dv=v.shape[-1])
        if plan is not None and plan.mode in ("heads", "feature"):
            from repro.kernels.sharded import hybrid_sharded
            _log_once(f"attention: hybrid-kernel {plan.describe()}")
            qh = normalize_qk(q) if spec.normalize else q
            kh = normalize_qk(k) if spec.normalize else k
            return hybrid_sharded(qh, kh, v, p=spec.p, window=spec.window,
                                  chunk_size=spec.chunk_size,
                                  denom_eps=spec.denom_eps, plan=plan)
        _log_once(
            "attention: hybrid-kernel under 'model' mesh without a "
            "kernel-shardable plan for this call (unpartitionable dims) "
            "-> chunked scan (feature-TP)")
        return _hybrid_chunked_fn(q, k, v, spec, causal=causal, kv_mask=None,
                                  rng=None, feature_shard=feature_shard)
    qh = normalize_qk(q) if spec.normalize else q
    kh = normalize_qk(k) if spec.normalize else k
    return kernel_ops.hybrid(qh, kh, v, p=spec.p, window=spec.window,
                             causal=causal, chunk_size=spec.chunk_size,
                             denom_eps=spec.denom_eps)


register(Backend(
    name="softmax",
    family="softmax",
    caps=Capabilities(decode=True, kv_mask=True),
    fn=_softmax_fn,
))

register(Backend(
    name="fastmax-oracle",
    family="fastmax",
    caps=Capabilities(),
    fn=_oracle_fn,
))

register(Backend(
    name="fastmax-rowwise",
    family="fastmax",
    caps=Capabilities(dropout=True),
    fn=_rowwise_fn,
))

register(Backend(
    name="fastmax-chunked",
    family="fastmax",
    caps=Capabilities(decode=True, kv_mask=True, feature_shard=True,
                      custom_grad=True),
    fn=_chunked_fn,
    fallback="fastmax-rowwise",   # dropout lives on the explicit-phi path
))

# NOTE kv_mask stays False here even though the forward kernel threads a
# mask: this capability describes the TRAINABLE attention() path, whose
# custom_vjp backward assumes no mask (as does the jnp §2.5 backward) — a
# masked call must reroute to chunked. The inference-only prefill protocol
# (repro.attention.prefill) uses the kernel's mask support directly.
# feature_shard=True: under a 'model' mesh the kernels run shard_map-
# wrapped (`repro.kernels.sharded`) — heads mode when kv heads divide the
# axis, else feature mode with the Dv-blocked backward launched per value-
# feature shard (causal training included; one psum of the partial dq/dk
# per launch; noncausal feature-TP wraps the kernel whose op pairs a jnp
# moment backward). Only unpartitionable dims or REPRO_FASTMAX_BWD=jnp
# fall back to the sharding-aware chunked scan, honoring the flag.
register(Backend(
    name="fastmax-kernel",
    family="fastmax",
    caps=Capabilities(decode=True, decode_kernel=True, custom_grad=True,
                      feature_shard=True,
                      platforms=("tpu",), interpretable=True),
    fn=_kernel_fn,
    fallback="fastmax-chunked",   # kv_mask / dropout reroute through chunked
))

register(Backend(
    name="hybrid-chunked",
    family="hybrid",
    caps=Capabilities(noncausal=False, decode=True, kv_mask=True,
                      feature_shard=True, custom_grad=True),
    fn=_hybrid_chunked_fn,
))

# decode_kernel stays False: hybrid decode state carries a rolling window
# KV cache alongside the moments, which the fused decode kernels don't
# model — prefill/step run the jnp protocol paths (repro.attention.state).
register(Backend(
    name="hybrid-kernel",
    family="hybrid",
    caps=Capabilities(noncausal=False, decode=True, custom_grad=True,
                      feature_shard=True,
                      platforms=("tpu",), interpretable=True),
    fn=_hybrid_kernel_fn,
    fallback="hybrid-chunked",    # kv_mask reroutes through chunked
))
