"""`attention(q, k, v, spec, ...)` — the single attention entry point.

Every model / serving / benchmark path computes attention through this
dispatcher: it resolves the spec's backend against the call's requirements
(capability-based routing, logged), derives cross-cutting flags (moment
feature-sharding under tensor parallelism), and invokes the backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.attention.registry import resolve
from repro.attention.spec import AttentionSpec

__all__ = ["attention", "feature_shard_flag"]


def feature_shard_flag(hkv: int) -> bool:
    """True when KV heads do NOT divide the 'model' axis of the active mesh
    (GQA/MQA at TP degree > Hkv): head-sharding can't use the axis, so the
    decode step switches to feature-TP — moments sharded on their feature
    (Dv) dims, shard-local one-token deltas, and a feature-sharded combine
    (`combine_with_queries(feature_shard=True)`)."""
    from repro.sharding.rules import active_mesh

    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    return hkv % mesh.shape["model"] != 0


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: Optional[AttentionSpec] = None,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,
    rng: Optional[jax.Array] = None,
    strict: bool = False,
) -> jnp.ndarray:
    """Compute attention per `spec`. q:[B,Hq,N,D]; k,v:[B,Hkv,M,*].

    `kv_mask` ([B,Hkv,M], 1=valid) exactly removes padding keys. `rng`
    enables the spec's dropout (training only). `strict=True` raises on any
    capability miss instead of routing to a capable backend.
    """
    if spec is None:
        spec = AttentionSpec()
    dropout = spec.dropout_rate > 0.0 and rng is not None
    backend = resolve(
        spec, causal=causal, dropout=dropout,
        kv_mask=kv_mask is not None, gqa=q.shape[1] != k.shape[1],
        strict=strict)
    # Moment feature-TP now applies to the full-sequence paths too: the
    # chunked scans stack their chunk inputs/outputs and constrain the
    # carry sharding-aware (rules.shard_stacked + _constrain_moments_j),
    # which removes the involuntary remats that previously made this
    # decode-step-only (ROADMAP; regression-gated by the dryrun's
    # xla_remat count).
    fs = backend.caps.feature_shard and feature_shard_flag(k.shape[1])
    return backend.fn(q, k, v, spec, causal=causal, kv_mask=kv_mask,
                      rng=rng, feature_shard=fs)
