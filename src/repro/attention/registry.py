"""Backend registry + capability-based resolution for attention operators.

Each backend declares what it can do (`Capabilities`). The resolver takes
the backend a spec requests plus the *requirements of this call* (causal?
dropout? kv_mask? platform?) and either returns the backend, or — when a
capability is missing — walks the backend's declared fallback chain and
LOGS the resolution (`strict=True` raises instead). This replaces the
seed's silent inline fallbacks (dropout -> rowwise inside `fastmax.py`,
kernel -> interpret inside `kernels/ops.py`) with one explicit, observable
routing step.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.attention.spec import AttentionSpec

__all__ = [
    "Capabilities",
    "Backend",
    "UnsupportedCapabilityError",
    "register",
    "get_backend",
    "list_backends",
    "resolve",
]

logger = logging.getLogger("repro.attention")

# log each distinct routing decision once per process (resolution happens
# at trace time; repeating it per layer/step would be noise)
_LOGGED: set = set()


def _log_once(msg: str) -> None:
    if msg not in _LOGGED:
        _LOGGED.add(msg)
        logger.info(msg)


class UnsupportedCapabilityError(ValueError):
    """A spec requested a capability its backend (and fallbacks) lack."""


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend supports. `platforms` lists compiled targets;
    `interpretable=True` means the same code runs off-platform in interpret
    mode (Pallas) rather than requiring a reroute."""

    causal: bool = True
    noncausal: bool = True
    decode: bool = False          # has a constant/streaming decode path
    decode_kernel: bool = False   # decode state lives in a fused Pallas
    #                               kernel (native AttnState moment carry)
    dropout: bool = False         # paper Fig. 2 factorized dropout
    gqa: bool = True              # grouped-query attention (Hq != Hkv)
    kv_mask: bool = False         # exact padding-token masking
    feature_shard: bool = False   # backend fn ACCEPTS moment feature-dim TP
    #                               sharding; attention() passes it whenever
    #                               the active mesh tensor-parallelizes over
    #                               kv heads that don't divide it (the
    #                               full-sequence scans stack their chunks
    #                               sharding-aware — docs/sharding.md), and
    #                               the decode step derives the same flag
    custom_grad: bool = False     # paper §2.5 memory-reduced backward
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    interpretable: bool = False


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered attention operator implementation.

    `fn(q, k, v, spec, *, causal, kv_mask, rng, feature_shard)` computes
    full-sequence attention. `fallback` names the backend to try when this
    one lacks a requested capability (chains are walked transitively).
    """

    name: str
    family: str
    caps: Capabilities
    fn: Callable
    fallback: Optional[str] = None


_REGISTRY: Dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no attention backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def list_backends() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def _ensure_builtins() -> None:
    # built-in backends live in their own module to avoid import cycles;
    # importing it populates the registry exactly once.
    from repro.attention import backends  # noqa: F401


def _missing(caps: Capabilities, *, causal: bool, dropout: bool,
             kv_mask: bool, gqa: bool) -> List[str]:
    need = []
    if causal and not caps.causal:
        need.append("causal")
    if not causal and not caps.noncausal:
        need.append("noncausal")
    if dropout and not caps.dropout:
        need.append("dropout")
    if kv_mask and not caps.kv_mask:
        need.append("kv_mask")
    if gqa and not caps.gqa:
        need.append("gqa")
    return need


def resolve(spec: AttentionSpec, *, causal: bool = False,
            dropout: bool = False, kv_mask: bool = False, gqa: bool = False,
            strict: bool = False) -> Backend:
    """Pick the backend that will run this call.

    Starts from `spec.backend_name`; on a capability miss walks the fallback
    chain (same family) and logs the reroute, or raises
    `UnsupportedCapabilityError` under `strict=True`. A platform miss on an
    `interpretable` backend is not a reroute — the backend runs in interpret
    mode — but is still logged.
    """
    _ensure_builtins()
    requested = get_backend(spec.backend_name)
    backend, seen = requested, set()
    while True:
        if backend.name in seen:  # defensive: cyclic fallback chain
            raise UnsupportedCapabilityError(
                f"cyclic fallback chain at {backend.name!r}")
        seen.add(backend.name)
        need = _missing(backend.caps, causal=causal, dropout=dropout,
                        kv_mask=kv_mask, gqa=gqa)
        if not need:
            break
        if strict:
            raise UnsupportedCapabilityError(
                f"backend {backend.name!r} (requested {spec.backend_name!r})"
                f" does not support: {', '.join(need)} (strict=True)")
        if backend.fallback is None:
            raise UnsupportedCapabilityError(
                f"no registered {backend.family} backend supports "
                f"{', '.join(need)} (requested {spec.backend_name!r})")
        nxt = get_backend(backend.fallback)
        _log_once(
            f"attention: {backend.name} lacks [{', '.join(need)}] -> "
            f"routing to {nxt.name}")
        backend = nxt

    platform = jax.default_backend()
    if platform not in backend.caps.platforms:
        if backend.caps.interpretable:
            _log_once(
                f"attention: {backend.name} targets "
                f"{'/'.join(backend.caps.platforms)}; platform={platform} "
                f"-> interpret mode")
        elif not strict and backend.fallback is not None:
            nxt = get_backend(backend.fallback)
            _log_once(
                f"attention: {backend.name} requires platform "
                f"{'/'.join(backend.caps.platforms)}; platform={platform} "
                f"-> routing to {nxt.name}")
            return resolve(
                dataclasses.replace(spec, impl=nxt.name.split("-")[-1])
                if backend.family in ("fastmax", "hybrid") else spec,
                causal=causal, dropout=dropout, kv_mask=kv_mask, gqa=gqa,
                strict=strict)
        else:
            # never silently run a non-interpretable backend off-platform
            raise UnsupportedCapabilityError(
                f"backend {backend.name!r} requires platform "
                f"{backend.caps.platforms}, running on {platform!r}")
    return backend
