"""Data pipeline: synthetic + memmap-backed token streams, host-sharded."""
from repro.data.pipeline import (  # noqa: F401
    MemmapDataset,
    SyntheticLM,
    make_batch_iterator,
    write_token_file,
)
