"""Token data pipeline.

Production-shaped but self-contained (no external datasets in this
container):

  * `SyntheticLM` — deterministic PRNG stream with learnable structure
    (repeated motifs + copy patterns) so small models visibly learn; used by
    the examples and the loss-curve benchmarks.
  * `MemmapDataset` — flat binary token file (np.memmap), the standard
    pretraining layout; `write_token_file` creates one.
  * `make_batch_iterator` — per-host sharding (each host reads only its
    slice: `host_id/host_count`), deterministic seeking by step for exact
    restart (fault tolerance: the iterator state is just `step`), and a
    background prefetch thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "MemmapDataset", "make_batch_iterator",
           "write_token_file"]


class SyntheticLM:
    """Deterministic synthetic LM stream with motif structure.

    Sequences mix (a) zipfian unigrams, (b) short repeated motifs, and
    (c) explicit copy segments (position t repeats position t-gap), giving
    both local and long-range learnable signal.
    """

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 n_motifs: int = 64, motif_len: int = 8):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab_size,
                                   size=(n_motifs, motif_len))

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        n, v = self.seq_len, self.vocab_size
        # zipf-ish unigrams
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(batch_size, n + 1), p=probs)
        # motif insertion
        for b in range(batch_size):
            for _ in range(max(1, n // 64)):
                m = self.motifs[rng.integers(len(self.motifs))]
                pos = rng.integers(0, n + 1 - len(m))
                toks[b, pos:pos + len(m)] = m
        # copy pattern in the second half
        gap = max(1, n // 4)
        half = (n + 1) // 2
        toks[:, half + gap:] = toks[:, half:-gap]
        tokens = toks[:, :-1].astype(np.int32)
        targets = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "targets": targets}


class MemmapDataset:
    """Flat binary int32 token file; standard pretraining layout."""

    def __init__(self, path: str, seq_len: int):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.n_seqs = (len(self.data) - 1) // seq_len

    def batch(self, step: int, batch_size: int, *, host_id: int = 0,
              host_count: int = 1) -> dict:
        n = self.seq_len
        per_host = batch_size // host_count
        idx0 = (step * batch_size + host_id * per_host) % max(
            1, self.n_seqs - per_host)
        rows = [(idx0 + i) % self.n_seqs for i in range(per_host)]
        tokens = np.stack([self.data[r * n:(r + 1) * n] for r in rows])
        targets = np.stack([self.data[r * n + 1:(r + 1) * n + 1]
                            for r in rows])
        return {"tokens": tokens.astype(np.int32),
                "targets": targets.astype(np.int32)}


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, dtype=np.int32).tofile(path)


def make_batch_iterator(source, batch_size: int, *, start_step: int = 0,
                        host_id: int = 0, host_count: int = 1,
                        prefetch: int = 2) -> Iterator[dict]:
    """Background-prefetched, restartable iterator. Deterministic in `step`
    — restart after preemption by passing the checkpointed step."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            kw = {}
            if isinstance(source, MemmapDataset):
                kw = {"host_id": host_id, "host_count": host_count}
            try:
                q.put((step, source.batch(step, batch_size, **kw)),
                      timeout=1.0)
            except queue.Full:
                continue
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _It:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _It()
