"""Self-contained distributed checkpointing (no orbax).

Layout: one directory per step —
    ckpt_dir/step_000100/
        manifest.json           tree structure, shapes, dtypes, step
        arrays/<leaf-id>.npy    one file per leaf (host-gathered)
    ckpt_dir/LATEST            atomic pointer (written last)

Properties needed at scale:
  * ATOMIC: data is written into a tmp dir and renamed; LATEST is updated
    only after the rename — a preempted save can never corrupt the
    previous checkpoint.
  * ASYNC: `CheckpointManager.save(..., block=False)` snapshots to host
    memory synchronously (cheap) and writes in a background thread so the
    train loop keeps stepping.
  * MESH-AGNOSTIC / ELASTIC: leaves are stored unsharded; restore reshards
    onto whatever mesh/sharding the new job uses (device count may differ —
    elastic data-axis rescale).
  * SELF-DESCRIBING: manifest carries the pytree structure; restore does
    not need the model code to enumerate leaves in the same order.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

# numpy can't persist extended dtypes (bf16, fp8) natively — store as a
# same-width uint view and record the logical dtype in the manifest
_EXT_DTYPE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                   "float8_e5m2": np.uint8}


def _to_saveable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXT_DTYPE_VIEW:
        return arr.view(_EXT_DTYPE_VIEW[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXT_DTYPE_VIEW:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, name))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    leaves, paths, treedef = _flatten(tree)
    tag = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{tag}")
    final = os.path.join(ckpt_dir, tag)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)

    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        saveable, dtype_name = _to_saveable(arr)
        fn = f"{i:05d}.npy"
        np.save(os.path.join(tmp, "arrays", fn), saveable)
        manifest["leaves"].append(
            {"path": path, "file": fn, "shape": list(arr.shape),
             "dtype": dtype_name})
    manifest["treedef"] = str(treedef)  # informational; restore uses `like`
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(tag)
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    tag = open(ptr).read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, tag)):
        return None
    return int(tag.split("_")[1])


def load_checkpoint(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
                    shardings: Any = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching tree of NamedSharding
    — leaves are placed (and thereby resharded) onto it: elastic restore.

    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))

    _, paths, treedef = _flatten(like)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    out = []
    for path, sh in zip(paths, shard_leaves):
        m = by_path[path]
        arr = _from_saved(np.load(os.path.join(d, "arrays", m["file"])),
                          m["dtype"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return (jax.tree_util.tree_unflatten(treedef, out), step,
            manifest.get("extra", {}))


class CheckpointManager:
    """Async save + retention. Snapshot is taken synchronously (device_get),
    disk write happens on a background thread; `wait()` joins in-flight
    writes (call before exit / next save)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = True):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()

        def work():
            save_checkpoint(self.dir, step, host_tree, extra)
            self._gc()

        if block:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, like, *, shardings=None, step=None):
        return load_checkpoint(self.dir, like, step=step,
                               shardings=shardings)

    def latest_step(self):
        return latest_step(self.dir)

    def _gc(self):
        tags = sorted(t for t in os.listdir(self.dir)
                      if t.startswith("step_"))
        for t in tags[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, t), ignore_errors=True)
