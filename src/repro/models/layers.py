"""Model layers: norms, RoPE, MLP, and attention with pluggable score backend.

The attention layer is where the paper's technique plugs in: the model
config's `attn: AttentionSpec` selects the operator (softmax baseline vs
the paper's fastmax p=1/2 polynomial kernels) and every call goes through
the `repro.attention` dispatcher. Everything else (GQA, qk-norm, biases,
RoPE, MLA) is orthogonal — FAST is a drop-in replacement for the score
computation, which is exactly the paper's §5 claim.

Decode states (repro.attention unified protocol):
  softmax  -> KVCache (O(N) per sequence)
  fastmax  -> Moments (O(D^2 Dv) per kv head, independent of context length)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import attention as A
from repro.attention import AttnState, KVCache  # noqa: F401 (re-export)
from repro.models.param import Builder

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(b: Builder, name: str, dim: int, norm_type: str = "rmsnorm"):
    sub = b.sub(name)
    sub.add("scale", (dim,), ("embed",), init="ones")
    if norm_type == "layernorm":
        sub.add("bias", (dim,), ("embed",), init="zeros")


def apply_norm(params, x, *, norm_type: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    elif norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32)
    else:
        raise ValueError(norm_type)
    return out.astype(x.dtype)


def rms_norm_headwise(x, eps: float = 1e-6):
    """Parameter-free per-head RMS norm (qk_norm without learned scale is
    handled by callers passing a scale param)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, H, N, D]; positions: [B, N] or [N]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,N,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------


def init_mlp(b: Builder, name: str, d_model: int, d_ff: int, act: str):
    sub = b.sub(name)
    if act == "swiglu":
        sub.add("wi_gate", (d_model, d_ff), ("embed", "ff"))
        sub.add("wi_up", (d_model, d_ff), ("embed", "ff"))
    else:
        sub.add("wi", (d_model, d_ff), ("embed", "ff"))
    sub.add("wo", (d_ff, d_model), ("ff", "embed"))


def apply_mlp(params, x, *, act: str):
    if act == "swiglu":
        g = jnp.einsum("bnd,df->bnf", x, params["wi_gate"])
        u = jnp.einsum("bnd,df->bnf", x, params["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bnd,df->bnf", x, params["wi"]))
    return jnp.einsum("bnf,fd->bnd", h, params["wo"])


# ---------------------------------------------------------------------------
# Attention (GQA + pluggable backend + optional MLA projections)
# ---------------------------------------------------------------------------
# KVCache / AttnState moved to repro.attention.state (re-exported above).


def init_attention(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.use_mla:
        rank = cfg.kv_lora_rank
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        sub.add("wq", (d, hq, qk_dim), ("embed", "heads", "head_dim"))
        sub.add("w_dkv", (d, rank + cfg.qk_rope_dim), ("embed", None))
        sub.add("w_uk", (rank, hq, cfg.qk_nope_dim), (None, "heads", "head_dim"))
        sub.add("w_uv", (rank, hq, hd), (None, "heads", "head_dim"))
        sub.add("wo", (hq, hd, d), ("heads", "head_dim", "embed"),
                fan_in=hq * hd)
    else:
        sub.add("wq", (d, hq, hd), ("embed", "heads", "head_dim"))
        sub.add("wk", (d, hkv, hd), ("embed", "kv_heads", "head_dim"))
        sub.add("wv", (d, hkv, hd), ("embed", "kv_heads", "head_dim"))
        sub.add("wo", (hq, hd, d), ("heads", "head_dim", "embed"),
                fan_in=hq * hd)
        if cfg.qkv_bias:
            sub.add("bq", (hq, hd), ("heads", "head_dim"), init="zeros")
            sub.add("bk", (hkv, hd), ("kv_heads", "head_dim"), init="zeros")
            sub.add("bv", (hkv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sub.add("q_norm_scale", (cfg.qk_nope_dim + cfg.qk_rope_dim
                                 if cfg.use_mla else hd,),
                (None,), init="ones")
        sub.add("k_norm_scale", (cfg.qk_nope_dim + cfg.qk_rope_dim
                                 if cfg.use_mla else hd,),
                (None,), init="ones")


def _project_qkv(params, x, cfg, positions):
    """Returns q:[B,Hq,N,Dq], k:[B,Hkv,N,Dq], v:[B,Hkv,N,Dv]."""
    if cfg.use_mla:
        q = jnp.einsum("bnd,dhk->bhnk", x, params["wq"])
        ckv = jnp.einsum("bnd,dr->bnr", x, params["w_dkv"])
        c, k_rope = (ckv[..., : cfg.kv_lora_rank],
                     ckv[..., cfg.kv_lora_rank:])
        k_nope = jnp.einsum("bnr,rhk->bhnk", c, params["w_uk"])
        v = jnp.einsum("bnr,rhk->bhnk", c, params["w_uv"])
        q_nope, q_rope = (q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:])
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)
        k_rope = jnp.broadcast_to(
            k_rope, (x.shape[0], q.shape[1], x.shape[1], cfg.qk_rope_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope], axis=-1)
        # MLA decompresses to per-(q)head k/v: treat as Hkv == Hq downstream
    else:
        q = jnp.einsum("bnd,dhk->bhnk", x, params["wq"])
        k = jnp.einsum("bnd,dhk->bhnk", x, params["wk"])
        v = jnp.einsum("bnd,dhk->bhnk", x, params["wv"])
        if cfg.qkv_bias:
            q = q + params["bq"][None, :, None, :]
            k = k + params["bk"][None, :, None, :]
            v = v + params["bv"][None, :, None, :]
        if cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.qk_norm:
        q = rms_norm_headwise(q) * params["q_norm_scale"]
        k = rms_norm_headwise(k) * params["k_norm_scale"]
    return q, k, v


def apply_attention(params, x, cfg, *, causal=True, positions=None,
                    kv_mask=None, kv_x: Optional[jnp.ndarray] = None):
    """Full-sequence attention. `kv_x` (cross-attention source) optional."""
    b, n, _ = x.shape
    if positions is None:
        positions = jnp.arange(n, dtype=jnp.int32)
    if kv_x is None:
        q, k, v = _project_qkv(params, x, cfg, positions)
    else:
        # cross-attention: q from x, k/v from kv_x (no causal, no rope on kv)
        m = kv_x.shape[1]
        kv_pos = jnp.arange(m, dtype=jnp.int32)
        q, _, _ = _project_qkv(params, x, cfg, positions)
        _, k, v = _project_qkv(params, kv_x, cfg, kv_pos)
    # grouped path: moments computed once per KV head (G-fold combine);
    # the head-sharded group reshape tiles cleanly because consecutive
    # q-head shards stay within one kv group (H/s <= G for all configs)
    o = A.attention(q, k, v, cfg.attn_spec, causal=causal, kv_mask=kv_mask)
    return jnp.einsum("bhnk,hkd->bnd", o.astype(x.dtype), params["wo"])


# -- decode (unified repro.attention state protocol) --------------------------


def _kv_dims(cfg):
    """(n_kv_heads, q_head_dim) as the decode state sees them (MLA
    decompresses to per-q-head k/v, so Hkv == Hq there)."""
    hkv = cfg.n_heads if cfg.use_mla else cfg.n_kv_heads
    dq = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.head_dim
    return hkv, dq


def init_attn_state(cfg, batch: int, max_len: int, dtype) -> AttnState:
    hkv, dq = _kv_dims(cfg)
    return A.init_state(cfg.attn_spec, batch=batch, n_kv_heads=hkv,
                        q_head_dim=dq, v_head_dim=cfg.head_dim,
                        max_len=max_len, dtype=dtype)


def attention_decode(params, x_t, state: AttnState, cfg, *, position):
    """One-token decode. x_t: [B, 1, d]. Returns (y_t, new_state).

    `position` is a scalar (shared timeline — the legacy serve loop) or a
    [B] vector (slot-indexed serving: every sequence sits at its own
    context length, so RoPE must rotate per slot)."""
    pos = jnp.atleast_1d(jnp.asarray(position, jnp.int32))[:, None]
    q, k, v = _project_qkv(params, x_t, cfg, pos)
    o, new = A.step(state, q, k, v, cfg.attn_spec)
    y = jnp.einsum("bhnk,hkd->bnd", o.astype(x_t.dtype), params["wo"])
    return y, new


def attention_prefill(params, x, state: AttnState, cfg, *, positions=None,
                      kv_mask=None, offset=None):
    """Prefill a prompt, returning outputs and a primed decode state.

    `offset`/`kv_mask` make it a resumable chunk prefill (repro.serve):
    the chunk's tokens occupy positions [offset, offset + n) and padding
    rows (kv_mask 0) contribute nothing to the carried state."""
    b, n, _ = x.shape
    if positions is None:
        off = 0 if offset is None else offset
        positions = off + jnp.arange(n, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    o, new = A.prefill(q, k, v, cfg.attn_spec, state=state, kv_mask=kv_mask,
                       offset=offset)
    y = jnp.einsum("bhnk,hkd->bnd", o.astype(x.dtype), params["wo"])
    return y, new
