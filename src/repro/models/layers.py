"""Model layers: norms, RoPE, MLP, and attention with pluggable score backend.

The attention layer is where the paper's technique plugs in: `attn_backend`
selects softmax (vanilla baseline), fastmax1, or fastmax2 (the paper's p=1/2
polynomial kernels). Everything else (GQA, qk-norm, biases, RoPE, MLA) is
orthogonal — FAST is a drop-in replacement for the score computation, which
is exactly the paper's §5 claim.

Decode states:
  softmax  -> KVCache (O(N) per sequence)
  fastmax* -> Moments (O(D^2 Dv) per kv head, independent of context length)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    Moments,
    fastmax_attention,
    fastmax_decode_step,
    fastmax_prefill,
    init_fastmax_state,
    softmax_attention,
)
from repro.models.param import Builder

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(b: Builder, name: str, dim: int, norm_type: str = "rmsnorm"):
    sub = b.sub(name)
    sub.add("scale", (dim,), ("embed",), init="ones")
    if norm_type == "layernorm":
        sub.add("bias", (dim,), ("embed",), init="zeros")


def apply_norm(params, x, *, norm_type: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    elif norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32)
    else:
        raise ValueError(norm_type)
    return out.astype(x.dtype)


def rms_norm_headwise(x, eps: float = 1e-6):
    """Parameter-free per-head RMS norm (qk_norm without learned scale is
    handled by callers passing a scale param)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, H, N, D]; positions: [B, N] or [N]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,N,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------


def init_mlp(b: Builder, name: str, d_model: int, d_ff: int, act: str):
    sub = b.sub(name)
    if act == "swiglu":
        sub.add("wi_gate", (d_model, d_ff), ("embed", "ff"))
        sub.add("wi_up", (d_model, d_ff), ("embed", "ff"))
    else:
        sub.add("wi", (d_model, d_ff), ("embed", "ff"))
    sub.add("wo", (d_ff, d_model), ("ff", "embed"))


def apply_mlp(params, x, *, act: str):
    if act == "swiglu":
        g = jnp.einsum("bnd,df->bnf", x, params["wi_gate"])
        u = jnp.einsum("bnd,df->bnf", x, params["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bnd,df->bnf", x, params["wi"]))
    return jnp.einsum("bnf,fd->bnd", h, params["wo"])


# ---------------------------------------------------------------------------
# Attention (GQA + pluggable backend + optional MLA projections)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray      # [B, Hkv, Nmax, D]
    v: jnp.ndarray      # [B, Hkv, Nmax, Dv]
    length: jnp.ndarray  # [] int32


class AttnState(NamedTuple):
    """Union decode state: exactly one of (kv, moments) is used."""
    kv: Optional[KVCache]
    moments: Optional[Moments]


def init_attention(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.use_mla:
        rank = cfg.kv_lora_rank
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        sub.add("wq", (d, hq, qk_dim), ("embed", "heads", "head_dim"))
        sub.add("w_dkv", (d, rank + cfg.qk_rope_dim), ("embed", None))
        sub.add("w_uk", (rank, hq, cfg.qk_nope_dim), (None, "heads", "head_dim"))
        sub.add("w_uv", (rank, hq, hd), (None, "heads", "head_dim"))
        sub.add("wo", (hq, hd, d), ("heads", "head_dim", "embed"),
                fan_in=hq * hd)
    else:
        sub.add("wq", (d, hq, hd), ("embed", "heads", "head_dim"))
        sub.add("wk", (d, hkv, hd), ("embed", "kv_heads", "head_dim"))
        sub.add("wv", (d, hkv, hd), ("embed", "kv_heads", "head_dim"))
        sub.add("wo", (hq, hd, d), ("heads", "head_dim", "embed"),
                fan_in=hq * hd)
        if cfg.qkv_bias:
            sub.add("bq", (hq, hd), ("heads", "head_dim"), init="zeros")
            sub.add("bk", (hkv, hd), ("kv_heads", "head_dim"), init="zeros")
            sub.add("bv", (hkv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sub.add("q_norm_scale", (cfg.qk_nope_dim + cfg.qk_rope_dim
                                 if cfg.use_mla else hd,),
                (None,), init="ones")
        sub.add("k_norm_scale", (cfg.qk_nope_dim + cfg.qk_rope_dim
                                 if cfg.use_mla else hd,),
                (None,), init="ones")


def _project_qkv(params, x, cfg, positions):
    """Returns q:[B,Hq,N,Dq], k:[B,Hkv,N,Dq], v:[B,Hkv,N,Dv]."""
    if cfg.use_mla:
        q = jnp.einsum("bnd,dhk->bhnk", x, params["wq"])
        ckv = jnp.einsum("bnd,dr->bnr", x, params["w_dkv"])
        c, k_rope = (ckv[..., : cfg.kv_lora_rank],
                     ckv[..., cfg.kv_lora_rank:])
        k_nope = jnp.einsum("bnr,rhk->bhnk", c, params["w_uk"])
        v = jnp.einsum("bnr,rhk->bhnk", c, params["w_uv"])
        q_nope, q_rope = (q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:])
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)
        k_rope = jnp.broadcast_to(
            k_rope, (x.shape[0], q.shape[1], x.shape[1], cfg.qk_rope_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope], axis=-1)
        # MLA decompresses to per-(q)head k/v: treat as Hkv == Hq downstream
    else:
        q = jnp.einsum("bnd,dhk->bhnk", x, params["wq"])
        k = jnp.einsum("bnd,dhk->bhnk", x, params["wk"])
        v = jnp.einsum("bnd,dhk->bhnk", x, params["wv"])
        if cfg.qkv_bias:
            q = q + params["bq"][None, :, None, :]
            k = k + params["bk"][None, :, None, :]
            v = v + params["bv"][None, :, None, :]
        if cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.qk_norm:
        q = rms_norm_headwise(q) * params["q_norm_scale"]
        k = rms_norm_headwise(k) * params["k_norm_scale"]
    return q, k, v


def _bcast_kv(k, hq):
    """Broadcast kv heads to q heads (kv-major repeat) — softmax path."""
    b, hkv, n, d = k.shape
    if hkv == hq:
        return k
    return jnp.repeat(k, hq // hkv, axis=1)


def _feature_shard_flag(hkv: int) -> bool:
    """True when KV heads do NOT divide the 'model' axis of the active mesh
    (GQA/MQA at TP degree > Hkv): the kv moment update would replicate
    TP-ways, so fastmax switches to token-sharded updates (partial moments
    + one small psum per chunk)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            from jax._src import mesh as mesh_lib
            mesh = mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return False
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    return hkv % mesh.shape["model"] != 0


def _run_backend(q, k, v, cfg, *, causal, kv_mask=None):
    if cfg.attn_backend == "softmax":
        k = _bcast_kv(k, q.shape[1])
        v = _bcast_kv(v, q.shape[1])
        if kv_mask is not None and kv_mask.shape[1] != q.shape[1]:
            kv_mask = jnp.repeat(kv_mask, q.shape[1] // kv_mask.shape[1],
                                 axis=1)
        return softmax_attention(q, k, v, causal=causal, kv_mask=kv_mask)
    p = 1 if cfg.attn_backend == "fastmax1" else 2
    # grouped path: moments computed once per KV head (G-fold combine);
    # the head-sharded group reshape tiles cleanly because consecutive
    # q-head shards stay within one kv group (H/s <= G for all configs)
    return fastmax_attention(
        q, k, v, p=p, causal=causal, impl=cfg.attn_impl,
        chunk_size=cfg.chunk_size, kv_mask=kv_mask,
        denom_eps=cfg.denom_eps,
        feature_shard=_feature_shard_flag(k.shape[1]),
    )


def apply_attention(params, x, cfg, *, causal=True, positions=None,
                    kv_mask=None, kv_x: Optional[jnp.ndarray] = None):
    """Full-sequence attention. `kv_x` (cross-attention source) optional."""
    b, n, _ = x.shape
    if positions is None:
        positions = jnp.arange(n, dtype=jnp.int32)
    if kv_x is None:
        q, k, v = _project_qkv(params, x, cfg, positions)
    else:
        # cross-attention: q from x, k/v from kv_x (no causal, no rope on kv)
        m = kv_x.shape[1]
        kv_pos = jnp.arange(m, dtype=jnp.int32)
        q, _, _ = _project_qkv(params, x, cfg, positions)
        _, k, v = _project_qkv(params, kv_x, cfg, kv_pos)
    o = _run_backend(q, k, v, cfg, causal=causal, kv_mask=kv_mask)
    return jnp.einsum("bhnk,hkd->bnd", o.astype(x.dtype), params["wo"])


# -- decode -----------------------------------------------------------------


def init_attn_state(cfg, batch: int, max_len: int, dtype) -> AttnState:
    hkv = cfg.n_heads if cfg.use_mla else cfg.n_kv_heads
    dq = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.head_dim
    if cfg.attn_backend == "softmax":
        kv = KVCache(
            k=jnp.zeros((batch, hkv, max_len, dq), dtype),
            v=jnp.zeros((batch, hkv, max_len, cfg.head_dim), dtype),
            length=jnp.zeros((), jnp.int32),
        )
        return AttnState(kv=kv, moments=None)
    p = 1 if cfg.attn_backend == "fastmax1" else 2
    mom = init_fastmax_state(batch, hkv, dq, cfg.head_dim, p=p,
                             dtype=jnp.float32)
    return AttnState(kv=None, moments=mom)


def attention_decode(params, x_t, state: AttnState, cfg, *, position):
    """One-token decode. x_t: [B, 1, d]. Returns (y_t, new_state)."""
    pos = jnp.reshape(position, (1,)).astype(jnp.int32)
    q, k, v = _project_qkv(params, x_t, cfg, pos)
    if cfg.attn_backend == "softmax":
        kv = state.kv
        kc = jax.lax.dynamic_update_slice_in_dim(
            kv.k, k.astype(kv.k.dtype), kv.length, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            kv.v, v.astype(kv.v.dtype), kv.length, axis=2)
        nmax = kc.shape[2]
        mask = (jnp.arange(nmax)[None, None, :] <= kv.length).astype(
            jnp.float32) * jnp.ones((x_t.shape[0], kc.shape[1], 1))
        o = softmax_attention(q, kc, vc, causal=False, kv_mask=mask)
        new = AttnState(kv=KVCache(kc, vc, kv.length + 1), moments=None)
    else:
        p = 1 if cfg.attn_backend == "fastmax1" else 2
        o, mom = fastmax_decode_step(state.moments, q, k, v, p=p,
                                     denom_eps=cfg.denom_eps)
        new = AttnState(kv=None, moments=mom)
    y = jnp.einsum("bhnk,hkd->bnd", o.astype(x_t.dtype), params["wo"])
    return y, new


def attention_prefill(params, x, state: AttnState, cfg, *, positions=None):
    """Prefill a prompt, returning outputs and a primed decode state."""
    b, n, _ = x.shape
    if positions is None:
        positions = jnp.arange(n, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    if cfg.attn_backend == "softmax":
        kv = state.kv
        kc = jax.lax.dynamic_update_slice_in_dim(
            kv.k, k.astype(kv.k.dtype), 0, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            kv.v, v.astype(kv.v.dtype), 0, axis=2)
        o = softmax_attention(q, k, v, causal=True)
        new = AttnState(kv=KVCache(kc, vc, jnp.asarray(n, jnp.int32)),
                        moments=None)
    else:
        p = 1 if cfg.attn_backend == "fastmax1" else 2
        # grouped path (moments shared per KV head); the carried moment
        # state stays per-KV-HEAD (moments never involve q)
        o = fastmax_attention(
            q, k, v, p=p, causal=True, impl=cfg.attn_impl,
            chunk_size=cfg.chunk_size, denom_eps=cfg.denom_eps,
            feature_shard=_feature_shard_flag(k.shape[1]))
        from repro.core.fastmax import (compute_moments_chunked,
                                        normalize_qk as _nq)
        mom = compute_moments_chunked(_nq(k), v, p=p,
                                      chunk_size=max(cfg.chunk_size, 512))
        new = AttnState(kv=None, moments=mom)
    y = jnp.einsum("bhnk,hkd->bnd", o.astype(x.dtype), params["wo"])
    return y, new
