"""Model substrate: layers, MoE, MLA, Mamba, xLSTM, transformer assembly."""
from repro.models.transformer import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    decode_state_specs,
    decode_step,
    init_decode_state,
    init_model,
    input_specs,
    model_forward,
    model_loss,
)
