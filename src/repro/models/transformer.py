"""Decoder-only LM assembly: config, blocks, scan-over-layers, decode.

A single `ModelConfig` expresses all 10 assigned architectures through a
repeating `pattern` of blocks ("mixer:ffn" strings):

  qwen2.5 / granite / qwen3 / llama3 / chameleon : ("attn:mlp",)
  deepseek-v2 / kimi-k2                          : ("attn:moe",) (+k dense)
  jamba          : ("mamba:mlp","mamba:moe","mamba:mlp","attn:moe",
                    "mamba:mlp","mamba:moe","mamba:mlp","mamba:moe")
  xlstm          : ("mlstm:none",)*7 + ("slstm:none",)

Layers are scanned (weights stacked on a leading "layers" axis) so HLO size
and compile time are O(1) in depth; `remat` selects the rematerialization
policy for the scan body.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.attention import AttentionSpec
from repro.models import layers as L
from repro.sharding.rules import maybe_constraint
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models.param import Builder

__all__ = ["ModelConfig", "init_lm", "forward_lm", "lm_loss",
           "init_lm_decode_state", "lm_decode_step", "lm_prefill"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"
    vocab_size: int = 32000
    d_model: int = 512
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    pattern: Tuple[str, ...] = ("attn:mlp",)
    first_k_dense: int = 0          # leading dense (non-MoE) blocks, unrolled
    # attention — one typed operator spec (see repro.attention); the legacy
    # attn_backend/attn_impl string pair is accepted as a deprecation shim
    attn: AttentionSpec = AttentionSpec()
    attn_backend: dataclasses.InitVar[Optional[str]] = None
    attn_impl: dataclasses.InitVar[Optional[str]] = None
    chunk_size: int = 128           # scan chunk (attention inherits; ssm too)
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4         # 0 disables rope
    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    # MLP / MoE
    mlp_act: str = "swiglu"
    n_experts: int = 0
    moe_top_k: int = 2
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ssm
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500
    cross_attention: bool = False
    pos_emb: str = "none"           # none | sinusoidal (frontends w/o rope)
    # norm / numerics
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_embeddings_only: bool = False  # encoder towers (no vocab/unembed)
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    remat: str = "full"             # none | dots | full
    logits_softcap: float = 0.0

    def __post_init__(self, attn_backend, attn_impl):
        if attn_backend or attn_impl:
            warnings.warn(
                "ModelConfig(attn_backend=..., attn_impl=...) is deprecated;"
                " pass attn=AttentionSpec(...) instead",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(
                self, "attn", self.attn.with_flags(backend=attn_backend,
                                                   impl=attn_impl))

    @property
    def attn_spec(self) -> AttentionSpec:
        """The attention spec with config-level defaults (chunk_size)
        resolved — what the layers hand to `repro.attention.attention`."""
        if self.attn.chunk_size is not None:
            return self.attn
        return dataclasses.replace(self.attn, chunk_size=self.chunk_size)

    @property
    def n_groups(self) -> int:
        assert self.n_layers_scanned % len(self.pattern) == 0, (
            self.n_layers_scanned, self.pattern)
        return self.n_layers_scanned // len(self.pattern)

    @property
    def n_layers_scanned(self) -> int:
        return self.n_layers - self.first_k_dense

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def adtype(self):
        return jnp.dtype(self.activ_dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _init_block(b: Builder, kind: str, cfg: ModelConfig,
                force_mlp: bool = False) -> None:
    mixer, ffn = kind.split(":")
    if force_mlp and ffn == "moe":
        ffn = "mlp"
    L.init_norm(b, "norm1", cfg.d_model, cfg.norm_type)
    if mixer == "attn":
        L.init_attention(b, "mixer", cfg)
        if cfg.cross_attention:
            L.init_norm(b, "norm_x", cfg.d_model, cfg.norm_type)
            L.init_attention(b, "cross", cfg)
    elif mixer == "mamba":
        M.init_mamba(b, "mixer", cfg)
    elif mixer == "mlstm":
        X.init_mlstm(b, "mixer", cfg)
    elif mixer == "slstm":
        X.init_slstm(b, "mixer", cfg)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        L.init_norm(b, "norm2", cfg.d_model, cfg.norm_type)
        L.init_mlp(b, "ffn", cfg.d_model, cfg.d_ff, cfg.mlp_act)
    elif ffn == "moe":
        L.init_norm(b, "norm2", cfg.d_model, cfg.norm_type)
        MOE.init_moe(b, "ffn", cfg)
    elif ffn != "none":
        raise ValueError(ffn)


def _apply_block(params, x, kind: str, cfg: ModelConfig, *, causal=True,
                 kv_mask=None, enc_out=None, force_mlp=False):
    mixer, ffn = kind.split(":")
    if force_mlp and ffn == "moe":
        ffn = "mlp"
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(params["norm1"], x, norm_type=cfg.norm_type,
                     eps=cfg.norm_eps)
    if mixer == "attn":
        y = L.apply_attention(params["mixer"], h, cfg, causal=causal,
                              kv_mask=kv_mask)
    elif mixer == "mamba":
        y = M.apply_mamba(params["mixer"], h, cfg)
    elif mixer == "mlstm":
        y = X.apply_mlstm(params["mixer"], h, cfg)
    elif mixer == "slstm":
        y = X.apply_slstm(params["mixer"], h, cfg)
    x = x + y
    if mixer == "attn" and cfg.cross_attention and enc_out is not None:
        h = L.apply_norm(params["norm_x"], x, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        x = x + L.apply_attention(params["cross"], h, cfg, causal=False,
                                  kv_x=enc_out)
    if ffn == "mlp":
        h = L.apply_norm(params["norm2"], x, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        x = x + L.apply_mlp(params["ffn"], h, act=cfg.mlp_act)
    elif ffn == "moe":
        h = L.apply_norm(params["norm2"], x, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        y, aux = MOE.apply_moe(params["ffn"], h, cfg)
        x = x + y
    return x, aux


# ---------------------------------------------------------------------------
# LM init / forward
# ---------------------------------------------------------------------------


def init_lm(key: jax.Array, cfg: ModelConfig, *, abstract: bool = False):
    """Returns (params, logical_axes). abstract=True -> ShapeDtypeStructs."""
    b = Builder(key, cfg.dtype(), abstract=abstract)
    if not cfg.input_embeddings_only:
        b.add("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              scale=1.0)
    for i in range(cfg.first_k_dense):
        _init_block(b.sub(f"dense_{i}"), cfg.pattern[0], cfg, force_mlp=True)
    for i, kind in enumerate(cfg.pattern):
        b.stacked(f"blocks_{i}", cfg.n_groups,
                  lambda pb, kind=kind: _init_block(pb, kind, cfg))
    L.init_norm(b, "final_norm", cfg.d_model, cfg.norm_type)
    if not cfg.tie_embeddings and not cfg.input_embeddings_only:
        b.add("unembed", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return b.params, b.axes


def _sinusoidal(n: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        # tied head: scale by 1/sqrt(d) (embeddings are unit-scale at init)
        logits = jnp.einsum("bnd,vd->bnv", x, params["embed"]) \
            * (cfg.d_model ** -0.5)
    else:
        logits = jnp.einsum("bnd,dv->bnv", x, params["unembed"])
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    if logits.ndim == 3:
        logits = maybe_constraint(logits, ("pod", "data"), None, "model")
    return logits


def forward_lm(params, tokens, cfg: ModelConfig, *, causal=True,
               kv_mask=None, embeddings=None, enc_out=None,
               return_hidden=False):
    """tokens: [B, N] int32 (or `embeddings` [B, N, d] for stub frontends)."""
    if embeddings is not None:
        x = embeddings.astype(cfg.adtype())
    else:
        x = params["embed"][tokens].astype(cfg.adtype())
    if cfg.pos_emb == "sinusoidal":
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    # keep activations batch-sharded (DP) and SEQUENCE-sharded over the
    # tensor axis between blocks (Megatron-SP): the scan-over-layers saved
    # residuals shrink by the TP degree; attention/MLP gather internally.
    # Also stops the FSDP (embed->data) weight sharding from propagating
    # into activations and replicating the batch.
    x = maybe_constraint(x, ("pod", "data"), "model", None)

    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.first_k_dense):
        x, aux = _apply_block(params[f"dense_{i}"], x, cfg.pattern[0], cfg,
                              causal=causal, kv_mask=kv_mask,
                              enc_out=enc_out, force_mlp=True)
        aux_total = aux_total + aux

    def group_body(carry, group_params):
        x, aux_sum = carry
        x = maybe_constraint(x, ("pod", "data"), "model", None)
        aux_g = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.pattern):
            x, aux = _apply_block(group_params[f"blocks_{i}"], x, kind, cfg,
                                  causal=causal, kv_mask=kv_mask,
                                  enc_out=enc_out)
            aux_g = aux_g + aux
        return (x, aux_sum + aux_g), None

    if cfg.remat == "full":
        group_body = jax.checkpoint(group_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    stacked = {f"blocks_{i}": params[f"blocks_{i}"]
               for i in range(len(cfg.pattern))}
    (x, aux_total), _ = jax.lax.scan(group_body, (x, aux_total), stacked)

    x = L.apply_norm(params["final_norm"], x, norm_type=cfg.norm_type,
                     eps=cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    return _logits(params, x, cfg), aux_total


def lm_loss(params, batch, cfg: ModelConfig):
    """Next-token cross-entropy. batch: {tokens, (targets|shift), loss_mask?}"""
    tokens = batch["tokens"]
    targets = batch.get("targets")
    if targets is None:
        targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    logits, aux = forward_lm(params, tokens, cfg,
                             embeddings=batch.get("embeddings"),
                             enc_out=batch.get("enc_out"))
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
        mask = mask.at[:, -1].set(0.0)
    nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serving): per-layer state, scanned over groups
# ---------------------------------------------------------------------------


def _init_block_state(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      dtype):
    mixer = kind.split(":")[0]
    if mixer == "attn":
        return L.init_attn_state(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return M.init_mamba_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return X.init_mlstm_state(cfg, batch)
    if mixer == "slstm":
        return X.init_slstm_state(cfg, batch, dtype)
    raise ValueError(mixer)


def init_lm_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    dtype = cfg.adtype()
    state = {}
    for i in range(cfg.first_k_dense):
        state[f"dense_{i}"] = _init_block_state(cfg.pattern[0], cfg, batch,
                                                max_len, dtype)
    for i, kind in enumerate(cfg.pattern):
        one = _init_block_state(kind, cfg, batch, max_len, dtype)
        state[f"blocks_{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_groups,) + x.shape).copy(), one)
    return state


def _decode_block(params, x_t, st, kind, cfg, *, position, enc_out=None):
    mixer, ffn = kind.split(":")
    h = L.apply_norm(params["norm1"], x_t, norm_type=cfg.norm_type,
                     eps=cfg.norm_eps)
    if mixer == "attn":
        y, st = L.attention_decode(params["mixer"], h, st, cfg,
                                   position=position)
    elif mixer == "mamba":
        y, st = M.mamba_decode(params["mixer"], h, st, cfg)
    elif mixer == "mlstm":
        y, st = X.mlstm_decode(params["mixer"], h, st, cfg)
    elif mixer == "slstm":
        y, st = X.slstm_decode(params["mixer"], h, st, cfg)
    x_t = x_t + y
    if mixer == "attn" and cfg.cross_attention and enc_out is not None:
        h = L.apply_norm(params["norm_x"], x_t, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        x_t = x_t + L.apply_attention(params["cross"], h, cfg, causal=False,
                                      kv_x=enc_out)
    if ffn in ("mlp", "moe"):
        h = L.apply_norm(params["norm2"], x_t, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        if ffn == "mlp" or "router" not in params.get("ffn", {}):
            x_t = x_t + L.apply_mlp(params["ffn"], h, act=cfg.mlp_act)
        else:
            y, _ = MOE.apply_moe(params["ffn"], h, cfg, full_capacity=True)
            x_t = x_t + y
    return x_t, st


def lm_decode_step(params, state, token_t, cfg: ModelConfig, *, position,
                   enc_out=None):
    """One token for the whole model. token_t: [B] int32. Returns
    (logits [B, vocab], new_state)."""
    x = params["embed"][token_t][:, None].astype(cfg.adtype())
    if cfg.pos_emb == "sinusoidal":
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)
        # position: scalar (shared timeline) or [B] (slot-indexed serving)
        pos = jnp.atleast_1d(jnp.asarray(position, jnp.float32))
        ang = pos[:, None] / jnp.power(10000.0, dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, None]
        x = x + pe.astype(x.dtype)

    for i in range(cfg.first_k_dense):
        x, st = _decode_block(params[f"dense_{i}"], x, state[f"dense_{i}"],
                              cfg.pattern[0], cfg, position=position,
                              enc_out=enc_out)
        state = {**state, f"dense_{i}": st}

    def group_body(carry, xs):
        x_t = carry
        group_params, group_state = xs
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            x_t, st = _decode_block(group_params[f"blocks_{i}"], x_t,
                                    group_state[f"blocks_{i}"], kind, cfg,
                                    position=position, enc_out=enc_out)
            new_states[f"blocks_{i}"] = st
        return x_t, new_states

    stacked_p = {f"blocks_{i}": params[f"blocks_{i}"]
                 for i in range(len(cfg.pattern))}
    stacked_s = {f"blocks_{i}": state[f"blocks_{i}"]
                 for i in range(len(cfg.pattern))}
    x, new_stacked = jax.lax.scan(group_body, x, (stacked_p, stacked_s))
    state = {**state, **new_stacked}
    x = L.apply_norm(params["final_norm"], x, norm_type=cfg.norm_type,
                     eps=cfg.norm_eps)
    return _logits(params, x, cfg)[:, 0], state


def lm_prefill(params, tokens, cfg: ModelConfig, state, *, enc_out=None,
               offset=None, kv_mask=None):
    """Prefill a prompt through the decode-state machinery.

    For fastmax archs this is the chunked causal scan per layer (linear in
    prompt length); for the softmax baseline it fills the KV cache.

    `offset` (traced scalar) resumes an already-primed state: this call's
    tokens occupy positions [offset, offset + n) — the serving engine's
    chunked-prefill tick (repro.serve). `kv_mask` ([B, N], 1 = real token)
    masks right-padding in a partial final chunk; padding contributes
    nothing to the carried attention state. SSM mixers (mamba/xlstm) resume
    through their own recurrent states but do not support kv_mask — the
    engine only pads chunks for attention-mixer architectures.
    """
    x = params["embed"][tokens].astype(cfg.adtype())
    if cfg.pos_emb == "sinusoidal":
        if offset is None:
            x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
        else:
            d = cfg.d_model
            dim = jnp.arange(0, d, 2, dtype=jnp.float32)
            pos = (offset + jnp.arange(x.shape[1])).astype(jnp.float32)
            ang = pos[:, None] / jnp.power(10000.0, dim / d)
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pe[None].astype(x.dtype)

    def block_prefill(params_b, x, st, kind):
        mixer, ffn = kind.split(":")
        h = L.apply_norm(params_b["norm1"], x, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        if mixer == "attn":
            y, st = L.attention_prefill(params_b["mixer"], h, st, cfg,
                                        kv_mask=kv_mask, offset=offset)
        elif mixer == "mamba":
            xi, z, delta, a, bm_, cm_, conv = M._pre_ssm(
                params_b["mixer"], h, cfg, conv_state=st.conv)
            yss, hf = M._selective_scan(
                xi.astype(jnp.float32), delta.astype(jnp.float32), a,
                bm_.astype(jnp.float32), cm_.astype(jnp.float32),
                params_b["mixer"]["D"].astype(jnp.float32),
                h0=st.h, chunk=cfg.chunk_size)
            y = jnp.einsum("bnd,de->bne",
                           yss.astype(h.dtype) * jax.nn.silu(z),
                           params_b["mixer"]["out_proj"])
            st = M.MambaState(conv=conv, h=hf)
        elif mixer == "mlstm":
            y, st = X.apply_mlstm_stateful(params_b["mixer"], h, cfg, st)
        elif mixer == "slstm":
            y, st = X.apply_slstm_stateful(params_b["mixer"], h, cfg, st)
        else:
            raise ValueError(mixer)
        x = x + y
        if mixer == "attn" and cfg.cross_attention and enc_out is not None:
            h = L.apply_norm(params_b["norm_x"], x, norm_type=cfg.norm_type,
                             eps=cfg.norm_eps)
            x = x + L.apply_attention(params_b["cross"], h, cfg, causal=False,
                                      kv_x=enc_out)
        if ffn in ("mlp", "moe"):
            h = L.apply_norm(params_b["norm2"], x, norm_type=cfg.norm_type,
                             eps=cfg.norm_eps)
            # first_k_dense blocks carry an MLP even in "moe" patterns
            if ffn == "mlp" or "router" not in params_b["ffn"]:
                x = x + L.apply_mlp(params_b["ffn"], h, act=cfg.mlp_act)
            else:
                y, _ = MOE.apply_moe(params_b["ffn"], h, cfg,
                                     full_capacity=True)
                x = x + y
        return x, st

    for i in range(cfg.first_k_dense):
        x, st = block_prefill(params[f"dense_{i}"], x, state[f"dense_{i}"],
                              cfg.pattern[0])
        state = {**state, f"dense_{i}": st}

    def group_body(x, xs):
        group_params, group_state = xs
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            x, st = block_prefill(group_params[f"blocks_{i}"], x,
                                  group_state[f"blocks_{i}"], kind)
            new_states[f"blocks_{i}"] = st
        return x, new_states

    stacked_p = {f"blocks_{i}": params[f"blocks_{i}"]
                 for i in range(len(cfg.pattern))}
    stacked_s = {f"blocks_{i}": state[f"blocks_{i}"]
                 for i in range(len(cfg.pattern))}
    x, new_stacked = jax.lax.scan(group_body, x, (stacked_p, stacked_s))
    state = {**state, **new_stacked}
    x = L.apply_norm(params["final_norm"], x, norm_type=cfg.norm_type,
                     eps=cfg.norm_eps)
    return _logits(params, x, cfg), state
