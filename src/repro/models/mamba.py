"""Mamba (selective SSM) mixer block — for the jamba hybrid architecture.

Chunked linear-recurrence evaluation: `lax.scan` over chunks of `chunk`
tokens with an associative scan inside each chunk, so peak memory is
O(B·chunk·d_inner·d_state) instead of O(B·N·d_inner·d_state), and training
backward stores only chunk-boundary states (same trick as the fastmax
chunked scan). Decode keeps (conv buffer, ssm state) — O(1) per token.

FAST applicability: none (attention-free mixer) — see DESIGN.md
§Arch-applicability. Included because jamba interleaves it 7:1 with
(fastmax-)attention layers.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.param import Builder

__all__ = ["init_mamba", "apply_mamba", "mamba_decode", "init_mamba_state",
           "MambaState"]


class MambaState(NamedTuple):
    conv: jnp.ndarray  # [B, d_conv-1, d_inner]
    h: jnp.ndarray     # [B, d_inner, d_state]


def _dims(cfg):
    di = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init_mamba(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d = cfg.d_model
    di, dt_rank, ds, dc = _dims(cfg)
    sub.add("in_proj", (d, 2 * di), ("embed", "ff"))
    sub.add("conv_w", (dc, di), (None, "ff"), scale=1.0 / math.sqrt(dc))
    sub.add("conv_b", (di,), ("ff",), init="zeros")
    sub.add("x_proj", (di, dt_rank + 2 * ds), ("ff", None))
    sub.add("dt_proj", (dt_rank, di), (None, "ff"),
            scale=dt_rank ** -0.5)
    sub.add("dt_bias", (di,), ("ff",), init="zeros")
    # S4D-real init: A = -[1..ds] per channel
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    sub.constant("A_log", jnp.log(a), ("ff", None))
    sub.add("D", (di,), ("ff",), init="ones")
    sub.add("out_proj", (di, d), ("ff", "embed"))


def _causal_conv(x, w, b_, *, state=None):
    """x: [B, N, di]; depthwise causal conv, kernel dc. state: last dc-1 in."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(dc))
    new_state = xp[:, -(dc - 1):] if dc > 1 else pad
    return out + b_[None, None, :], new_state


def _selective_scan(u, delta, a, bmat, cmat, d_skip, *, h0, chunk=128):
    """h_t = exp(Δ_t A)·h_{t-1} + Δ_t·B_t·u_t ;  y_t = C_t·h_t + D·u_t.

    u, delta: [B, N, di]; bmat, cmat: [B, N, ds]; a: [di, ds];
    h0: [B, di, ds]. Chunked associative scan (memory O(B·chunk·di·ds)).
    Returns (y [B,N,di], h_final).
    """
    bsz, n, di = u.shape
    ds = a.shape[-1]
    cs = min(chunk, n)
    nc = -(-n // cs)
    pad = nc * cs - n
    up = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    dp = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
    bp = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
    cp = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    chunks = lambda x: jnp.moveaxis(  # noqa: E731
        x.reshape(bsz, nc, cs, x.shape[-1]), 1, 0)

    def body(h, xs):
        uc, dc_, bc, cc = xs                                  # [B, cs, *]
        da = jnp.exp(dc_[..., None] * a[None, None])          # [B,cs,di,ds]
        dbu = (dc_ * uc)[..., None] * bc[:, :, None, :]       # [B,cs,di,ds]

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (da, dbu), axis=1)
        hseq = a_cum * h[:, None] + b_cum                     # [B,cs,di,ds]
        y = jnp.einsum("bcds,bcs->bcd", hseq, cc)
        return hseq[:, -1], y

    hf, ys = jax.lax.scan(body, h0, (chunks(up), chunks(dp), chunks(bp),
                                     chunks(cp)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * cs, di)[:, :n]
    return y + u * d_skip[None, None, :], hf


def _pre_ssm(params, x, cfg, conv_state=None):
    di, dt_rank, ds, _ = _dims(cfg)
    xz = jnp.einsum("bnd,de->bne", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, new_conv = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                state=conv_state)
    xi = jax.nn.silu(xi)
    proj = jnp.einsum("bnd,de->bne", xi, params["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bnr,rd->bnd", dt, params["dt_proj"]) + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    return xi, z, delta, a, bmat, cmat, new_conv


def apply_mamba(params, x, cfg):
    bsz, n, d = x.shape
    di, _, ds, _ = _dims(cfg)
    xi, z, delta, a, bmat, cmat, _ = _pre_ssm(params, x, cfg)
    h0 = jnp.zeros((bsz, di, ds), jnp.float32)
    y, _ = _selective_scan(
        xi.astype(jnp.float32), delta.astype(jnp.float32), a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        params["D"].astype(jnp.float32), h0=h0, chunk=cfg.chunk_size)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bnd,de->bne", y, params["out_proj"])


def init_mamba_state(cfg, batch: int, dtype) -> MambaState:
    di, _, ds, dc = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, dc - 1, di), dtype),
        h=jnp.zeros((batch, di, ds), jnp.float32),
    )


def mamba_decode(params, x_t, state: MambaState, cfg):
    """One-token decode. x_t: [B, 1, d]."""
    xi, z, delta, a, bmat, cmat, new_conv = _pre_ssm(
        params, x_t, cfg, conv_state=state.conv)
    da = jnp.exp(delta[:, 0, :, None].astype(jnp.float32) * a[None])
    dbu = (delta * xi)[:, 0, :, None].astype(jnp.float32) \
        * bmat[:, 0, None, :].astype(jnp.float32)
    h = da * state.h + dbu
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0].astype(jnp.float32))
    y = y + xi[:, 0].astype(jnp.float32) * params["D"].astype(jnp.float32)
    y = (y[:, None].astype(x_t.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bnd,de->bne", y, params["out_proj"])
    return out, MambaState(conv=new_conv, h=h)
