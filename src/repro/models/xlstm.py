"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Attention-free architecture — FAST is inapplicable here (DESIGN.md
§Arch-applicability); built faithfully as an assigned architecture.

mLSTM is evaluated CHUNKWISE (gated-linear-attention form): within a chunk
the gate-weighted f(QKᵀ)-style block is computed directly; across chunks the
matrix memory C (and normalizer n) are carried. All decay ratios are ≤ 1 by
construction (cumulative log-sigmoid forget gates), input gates are
exp-capped, so the unstabilized chunk math is safe in fp32.

sLSTM has true recurrent gate connections (h_{t-1} enters the gates), so it
is strictly sequential: lax.scan over time. It appears once per 8 blocks
(xLSTM[7:1]), so the sequential cost is bounded.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.param import Builder

__all__ = [
    "init_mlstm", "apply_mlstm", "mlstm_decode", "init_mlstm_state",
    "init_slstm", "apply_slstm", "slstm_decode", "init_slstm_state",
    "MLSTMState", "SLSTMState",
]

_ICAP = 10.0  # input-gate exp cap (numerical guard)


def _dims(cfg):
    di = 2 * cfg.d_model             # proj_factor 2 (xLSTM-1.3b)
    nh = cfg.n_heads
    hd = di // nh
    return di, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, H, dk, dv]
    n: jnp.ndarray   # [B, H, dk]


def init_mlstm(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d = cfg.d_model
    di, nh, hd = _dims(cfg)
    sub.add("up_proj", (d, 2 * di), ("embed", "ff"))
    # headwise (block-diagonal) q/k/v projections, per the xLSTM paper
    sub.add("wq", (nh, hd, hd), ("heads", None, "head_dim"), fan_in=hd)
    sub.add("wk", (nh, hd, hd), ("heads", None, "head_dim"), fan_in=hd)
    sub.add("wv", (nh, hd, hd), ("heads", None, "head_dim"), fan_in=hd)
    sub.add("wi", (di, nh), ("ff", "heads"), scale=0.02)
    sub.add("wf", (di, nh), ("ff", "heads"), scale=0.02)
    sub.add("bi", (nh,), ("heads",), init="zeros")
    # positive forget bias -> long memory at init (paper init)
    sub.constant("bf", jnp.full((nh,), 3.0, jnp.float32), ("heads",))
    sub.add("gn_scale", (di,), ("ff",), init="ones")
    sub.add("down_proj", (di, d), ("ff", "embed"))


def _mlstm_gates(params, xi):
    """xi: [B, N, di] -> (q, k, v [B,H,N,hd], log_f [B,H,N], i [B,H,N])."""
    nh, hd = params["wq"].shape[0], params["wq"].shape[1]
    xh = xi.reshape(xi.shape[0], xi.shape[1], nh, hd)
    q = jnp.einsum("bnhk,hkl->bhnl", xh, params["wq"])
    k = jnp.einsum("bnhk,hkl->bhnl", xh, params["wk"]) / math.sqrt(hd)
    v = jnp.einsum("bnhk,hkl->bhnl", xh, params["wv"])
    fpre = jnp.einsum("bnd,dh->bhn", xi, params["wf"]) + params["bf"][:, None]
    ipre = jnp.einsum("bnd,dh->bhn", xi, params["wi"]) + params["bi"][:, None]
    log_f = jax.nn.log_sigmoid(fpre.astype(jnp.float32))
    ig = jnp.exp(jnp.minimum(ipre.astype(jnp.float32), _ICAP))
    return q, k, v, log_f, ig


def _mlstm_chunk_scan(q, k, v, log_f, ig, c0, n0, *, chunk):
    """Chunked gated linear attention. q,k,v: [B,H,N,hd] (fp32)."""
    bsz, nh, n, hd = q.shape
    dv = v.shape[-1]
    cs = min(chunk, n)
    nc = -(-n // cs)
    pad = nc * cs - n
    pad4 = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))  # noqa
    pad3 = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad)))          # noqa
    ch4 = lambda x: jnp.moveaxis(                                     # noqa
        pad4(x).reshape(bsz, nh, nc, cs, x.shape[-1]), 2, 0)
    ch3 = lambda x: jnp.moveaxis(                                     # noqa
        pad3(x).reshape(bsz, nh, nc, cs), 2, 0)

    def body(carry, xs):
        c_prev, n_prev = carry
        qc, kc, vc, lfc, igc = xs
        lcum = jnp.cumsum(lfc, axis=-1)                   # [B,H,cs] ≤ 0
        # intra: w_ij = exp(lcum_i - lcum_j) * ig_j , j <= i  (ratio ≤ 1)
        ratio = jnp.exp(lcum[..., :, None] - lcum[..., None, :])
        tri = jnp.tril(jnp.ones((cs, cs), jnp.float32))
        w = ratio * igc[..., None, :] * tri
        s = jnp.einsum("bhik,bhjk->bhij", qc, kc) * w
        num = jnp.einsum("bhij,bhjv->bhiv", s, vc)
        den = jnp.einsum("bhij,bhjk,bhik->bhi", w, kc, qc)
        # inter: scale by exp(lcum_i)
        scale_i = jnp.exp(lcum)
        num = num + scale_i[..., None] * jnp.einsum(
            "bhik,bhkv->bhiv", qc, c_prev)
        den = den + scale_i * jnp.einsum("bhik,bhk->bhi", qc, n_prev)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update: decay by total chunk forget, add chunk contributions
        tot = lcum[..., -1:]
        dec_j = jnp.exp(tot - lcum) * igc                  # [B,H,cs]
        c_new = jnp.exp(tot)[..., None] * c_prev + jnp.einsum(
            "bhjk,bhjv,bhj->bhkv", kc, vc, dec_j)
        n_new = jnp.exp(tot) * n_prev + jnp.einsum("bhjk,bhj->bhk", kc, dec_j)
        return (c_new, n_new), h

    (cf, nf), hs = jax.lax.scan(
        body, (c0, n0), (ch4(q), ch4(k), ch4(v), ch3(log_f), ch3(ig)))
    h = jnp.moveaxis(hs, 0, 2).reshape(bsz, nh, nc * cs, dv)[:, :, :n]
    return h, (cf, nf)


def apply_mlstm_stateful(params, x, cfg, state: "MLSTMState"):
    bsz, n, d = x.shape
    di, nh, hd = _dims(cfg)
    ug = jnp.einsum("bnd,de->bne", x, params["up_proj"])
    xi, z = jnp.split(ug, 2, axis=-1)
    q, k, v, log_f, ig = _mlstm_gates(params, xi)
    h, (cf, nf) = _mlstm_chunk_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_f, ig, state.c, state.n, chunk=min(cfg.chunk_size, 128))
    h = jnp.moveaxis(h, 1, 2).reshape(bsz, n, di).astype(x.dtype)
    # headwise group norm (scale only)
    hn = h.reshape(bsz, n, nh, hd)
    var = jnp.mean(jnp.square(hn.astype(jnp.float32)), axis=-1, keepdims=True)
    hn = (hn * jax.lax.rsqrt(var + 1e-6)).reshape(bsz, n, di)
    h = hn.astype(x.dtype) * params["gn_scale"] * jax.nn.silu(z)
    out = jnp.einsum("bnd,de->bne", h, params["down_proj"])
    return out, MLSTMState(c=cf, n=nf)


def apply_mlstm(params, x, cfg):
    return apply_mlstm_stateful(params, x, cfg,
                                init_mlstm_state(cfg, x.shape[0]))[0]


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    _, nh, hd = _dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, nh, hd, hd), jnp.float32),
        n=jnp.zeros((batch, nh, hd), jnp.float32),
    )


def mlstm_decode(params, x_t, state: MLSTMState, cfg):
    bsz, _, d = x_t.shape
    di, nh, hd = _dims(cfg)
    ug = jnp.einsum("bnd,de->bne", x_t, params["up_proj"])
    xi, z = jnp.split(ug, 2, axis=-1)
    q, k, v, log_f, ig = _mlstm_gates(params, xi)
    q, k, v = (t[:, :, 0].astype(jnp.float32) for t in (q, k, v))
    f = jnp.exp(log_f[..., 0])
    i = ig[..., 0]
    c = f[..., None, None] * state.c + i[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    nn = f[..., None] * state.n + i[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    den = jnp.einsum("bhk,bhk->bh", q, nn)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    h = h.reshape(bsz, 1, di)
    var = jnp.mean(jnp.square(h.reshape(bsz, 1, nh, hd)), axis=-1,
                   keepdims=True)
    hn = (h.reshape(bsz, 1, nh, hd) * jax.lax.rsqrt(var + 1e-6)).reshape(
        bsz, 1, di)
    h = hn.astype(x_t.dtype) * params["gn_scale"] * jax.nn.silu(z)
    out = jnp.einsum("bnd,de->bne", h, params["down_proj"])
    return out, MLSTMState(c=c, n=nn)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # [B, di]
    n: jnp.ndarray  # [B, di]
    m: jnp.ndarray  # [B, di]  log-stabilizer
    h: jnp.ndarray  # [B, di]


def _sdims(cfg):
    di = cfg.d_model                 # sLSTM operates at model width
    nh = cfg.n_heads
    return di, nh, di // nh


def init_slstm(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d = cfg.d_model
    di, nh, hd = _sdims(cfg)
    for gate in ("z", "i", "f", "o"):
        sub.add(f"w{gate}", (d, di), ("embed", "ff"))
        # recurrent weights: block-diagonal per head [H, hd, hd]
        sub.add(f"r{gate}", (nh, hd, hd), ("heads", None, None), fan_in=hd)
        sub.add(f"b{gate}", (di,), ("ff",),
                init="zeros" if gate != "f" else "ones")
    sub.add("gn_scale", (di,), ("ff",), init="ones")
    sub.add("down_proj", (di, d), ("ff", "embed"))


def _slstm_step(params, carry, x_t, nh, hd):
    c, n, m, h = carry
    bsz = x_t.shape[0]
    hh = h.reshape(bsz, nh, hd)

    def gate(name):
        wx = x_t @ params[f"w{name}"]
        rh = jnp.einsum("bhk,hkl->bhl", hh, params[f"r{name}"]).reshape(
            bsz, nh * hd)
        return wx + rh + params[f"b{name}"]

    z = jnp.tanh(gate("z"))
    o = jax.nn.sigmoid(gate("o"))
    itil = gate("i").astype(jnp.float32)
    ftil = gate("f").astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(ftil)
    m_new = jnp.maximum(log_f + m, itil)
    i_p = jnp.exp(itil - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z.astype(jnp.float32)
    n_new = f_p * n + i_p
    h_new = (o.astype(jnp.float32) * c_new
             / jnp.maximum(n_new, 1e-6)).astype(x_t.dtype)
    return (c_new, n_new, m_new, h_new), h_new


def apply_slstm_stateful(params, x, cfg, state: "SLSTMState"):
    bsz, n, d = x.shape
    di, nh, hd = _sdims(cfg)
    carry = (state.c, state.n, state.m, state.h)

    def body(c_, x_t):
        return _slstm_step(params, c_, x_t, nh, hd)

    (cf, nf, mf, hf), hs = jax.lax.scan(body, carry, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                       # [B, N, di]
    var = jnp.mean(jnp.square(h.reshape(bsz, n, nh, hd).astype(jnp.float32)),
                   axis=-1, keepdims=True)
    hn = (h.reshape(bsz, n, nh, hd) * jax.lax.rsqrt(var + 1e-6)).reshape(
        bsz, n, di).astype(x.dtype)
    out = jnp.einsum("bnd,de->bne", hn * params["gn_scale"],
                     params["down_proj"])
    return out, SLSTMState(c=cf, n=nf, m=mf, h=hf)


def apply_slstm(params, x, cfg):
    return apply_slstm_stateful(
        params, x, cfg, init_slstm_state(cfg, x.shape[0], x.dtype))[0]


def init_slstm_state(cfg, batch: int, dtype) -> SLSTMState:
    di, _, _ = _sdims(cfg)
    return SLSTMState(
        c=jnp.zeros((batch, di), jnp.float32),
        n=jnp.zeros((batch, di), jnp.float32),
        m=jnp.full((batch, di), -1e9, jnp.float32),
        h=jnp.zeros((batch, di), dtype),
    )


def slstm_decode(params, x_t, state: SLSTMState, cfg):
    bsz, _, d = x_t.shape
    di, nh, hd = _sdims(cfg)
    carry = (state.c, state.n, state.m, state.h)
    (c, n, m, h), h_out = _slstm_step(params, carry, x_t[:, 0], nh, hd)
    var = jnp.mean(jnp.square(h_out.reshape(bsz, nh, hd).astype(jnp.float32)),
                   axis=-1, keepdims=True)
    hn = (h_out.reshape(bsz, nh, hd) * jax.lax.rsqrt(var + 1e-6)).reshape(
        bsz, di).astype(x_t.dtype)
    out = jnp.einsum("bd,de->be", hn * params["gn_scale"],
                     params["down_proj"])[:, None]
    return out, SLSTMState(c=c, n=n, m=m, h=h)
