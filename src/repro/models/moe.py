"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

Dispatch is index/scatter-based (NOT the [T, E, C] one-hot einsum — that
tensor is ~10 TB/device for kimi-k2-scale configs). Per device:

  1. router: softmax over experts, top-k per token, renormalized gates
  2. position-in-expert via a masked cumulative sum, tokens over capacity
     C = ceil(k * T * capacity_factor / E) are dropped (standard capacity
     dropping — gradient still flows to kept slots)
  3. scatter tokens into an [E, C, d] buffer, run all experts as a batched
     einsum (weights [E, d, ff] sharded "experts" -> EP axis), gather back
     and combine with gates.

Aux load-balance loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.param import Builder

__all__ = ["init_moe", "apply_moe"]


def init_moe(b: Builder, name: str, cfg) -> None:
    sub = b.sub(name)
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    sub.add("router", (d, e), ("embed", "experts"), scale=0.02)
    sub.add("wi_gate", (e, d, ff), ("experts", "embed", "ff"), fan_in=d)
    sub.add("wi_up", (e, d, ff), ("experts", "embed", "ff"), fan_in=d)
    sub.add("wo", (e, ff, d), ("experts", "ff", "embed"), fan_in=ff)
    if cfg.n_shared_experts > 0:
        sff = ff * cfg.n_shared_experts
        sub.add("shared_wi_gate", (d, sff), ("embed", "ff"))
        sub.add("shared_wi_up", (d, sff), ("embed", "ff"))
        sub.add("shared_wo", (sff, d), ("ff", "embed"))


def apply_moe(params, x, cfg, *, full_capacity: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, N, d]. Returns (y, aux_loss).

    full_capacity=True sizes buffers so NO token is ever dropped — the
    inference (prefill/decode) mode; training uses capacity dropping."""
    b, n, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * n
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gates, idx = jax.lax.top_k(probs, k)                       # [T, k]
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    if full_capacity and t <= 4096:
        capacity = t                 # decode/small-prefill: never drop
    elif full_capacity:
        # long prefill: worst-case capacity is infeasible (t ~ 1M tokens);
        # 2x the expected load makes drops vanishingly rare at this T
        capacity = min(t, max(1, int(2.0 * k * t / e)))
    else:
        capacity = max(1, int(k * t * cfg.capacity_factor / e))

    # position of each (token, slot) within its expert, by token order.
    # top_k experts are DISTINCT per token, so a [T, E] 0/1 mask suffices —
    # never materialize [T, k, E] (≈1 GB/device at kimi-k2 scale).
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    slot_mask = jnp.zeros((t, e), jnp.int32).at[token_ids, idx].add(1)
    pos_before = jnp.cumsum(slot_mask, axis=0) - slot_mask     # tokens before t
    pos = jnp.take_along_axis(pos_before, idx, axis=1)         # [T, k]
    keep = pos < capacity                                      # [T, k]
    pos_c = jnp.where(keep, pos, 0)

    # scatter into expert buffers; buffer sharded E->EP ("model") and
    # C->"data" so the per-device slice stays ~capacity/ep_size tokens.
    from repro.sharding.rules import maybe_constraint
    buf = jnp.zeros((e, capacity, d), xf.dtype)
    buf = maybe_constraint(buf, "model", "data", None)
    flat_e = idx.reshape(-1)
    flat_p = pos_c.reshape(-1)
    src = jnp.repeat(xf[:, None, :], k, axis=1).reshape(t * k, d)
    src = src * keep.reshape(-1, 1).astype(src.dtype)
    buf = buf.at[flat_e, flat_p].add(src)
    buf = maybe_constraint(buf, "model", "data", None)

    # expert FFN (batched over E; "experts" dim sharded -> expert parallel)
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"])          # [E, C, d]

    # gather back + combine
    y_tk = y_e[flat_e, flat_p].reshape(t, k, d)
    y = jnp.sum(
        y_tk * (gates * keep.astype(gates.dtype))[..., None].astype(y_tk.dtype),
        axis=1,
    )

    # shared experts (always-on dense path, DeepSeek-style)
    if cfg.n_shared_experts > 0:
        sg = jnp.einsum("td,df->tf", xf, params["shared_wi_gate"])
        su = jnp.einsum("td,df->tf", xf, params["shared_wi_up"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                           params["shared_wo"])

    # Switch-style load balance aux: E * Σ_e (frac_tokens_e · frac_prob_e)
    me = jnp.mean(probs, axis=0)                               # [E]
    counts = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = counts / (t * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return y.reshape(b, n, d).astype(x.dtype), aux
