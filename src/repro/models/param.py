"""Parameter construction with logical sharding axes (MaxText-style).

Pure-JAX (no flax): params are nested dicts of arrays. A `Builder` constructs
two parallel trees — values and logical-axis tuples — so sharding rules
(repro.sharding) can map every parameter to a PartitionSpec without a
separately-maintained spec tree.

Logical axis vocabulary (see repro/sharding/rules.py):
  "embed"   — model width (d_model)        -> fsdp axis for big models
  "heads"   — attention heads              -> tensor parallel
  "kv_heads"— kv heads (GQA)               -> tensor parallel iff divisible
  "head_dim"— per-head dim                 -> replicated
  "ff"      — MLP hidden                   -> tensor parallel
  "vocab"   — embedding/logit vocab        -> tensor parallel
  "experts" — MoE experts                  -> expert parallel
  "layers"  — stacked scan-over-layers     -> replicated (leading axis)
  None      — replicated
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Builder", "count_params", "tree_bytes"]


class Builder:
    """Collects (value, logical_axes) pairs into parallel nested dicts.

    `abstract=True` builds ShapeDtypeStruct leaves (no RNG, no allocation) —
    used by the dry-run to get shapes+axes for full-size configs.
    """

    def __init__(self, key: jax.Array, dtype=jnp.float32,
                 abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def _next_key(self) -> jax.Array:
        if self.abstract:
            return self._key
        self._key, sub = jax.random.split(self._key)
        return sub

    def sub(self, name: str) -> "Builder":
        child = Builder(self._next_key(), self.dtype, self.abstract)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def constant(self, name: str, value, axes) -> None:
        """Insert a concrete constant parameter (e.g. S4D A_log init)."""
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(
                value.shape, jnp.dtype(self.dtype))
        else:
            self.params[name] = value.astype(self.dtype)
        self.axes[name] = tuple(axes)

    def add(
        self,
        name: str,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        init: str = "normal",
        scale: Optional[float] = None,
        fan_in: Optional[int] = None,
    ) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(
                tuple(shape), jnp.dtype(self.dtype))
            self.axes[name] = tuple(axes)
            return
        if init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "normal":
            if scale is None:
                fi = fan_in if fan_in is not None else shape[0]
                scale = 1.0 / math.sqrt(max(1, fi))
            val = (jax.random.normal(self._next_key(), shape, jnp.float32)
                   * scale).astype(self.dtype)
        else:
            raise ValueError(init)
        self.params[name] = val
        self.axes[name] = tuple(axes)

    def stacked(self, name: str, n: int,
                make: Callable[["Builder"], None]) -> None:
        """Init `n` copies of a submodule stacked on a leading 'layers' axis
        (scan-over-layers). `make` populates a prototype builder."""
        proto = Builder(jax.random.PRNGKey(0), self.dtype,
                        abstract=self.abstract)
        make(proto)  # structure/axes only; values re-drawn per layer below

        if self.abstract:
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                proto.params,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        else:
            keys = jax.random.split(self._next_key(), n)

            def init_one(k):
                b = Builder(k, self.dtype)
                make(b)
                return b.params

            stacked = jax.vmap(init_one)(keys)
        self.params[name] = stacked
        self.axes[name] = jax.tree.map(
            lambda ax: ("layers",) + ax, proto.axes,
            is_leaf=lambda x: isinstance(x, tuple),
        )


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
