"""Model registry: init / loss / decode entry points + input specs.

`input_specs()` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input — the dry-run lowers
against these.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models.transformer import (
    ModelConfig,
    forward_lm,
    init_lm,
    init_lm_decode_state,
    lm_decode_step,
    lm_loss,
    lm_prefill,
)

__all__ = ["init_model", "model_loss", "model_forward", "input_specs",
           "decode_state_specs", "init_decode_state", "decode_step"]


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


def init_model(key: jax.Array, cfg: ModelConfig, *, abstract: bool = False):
    if _is_encdec(cfg):
        return ED.init_encdec(key, cfg, abstract=abstract)
    return init_lm(key, cfg, abstract=abstract)


def model_loss(params, batch, cfg: ModelConfig):
    if _is_encdec(cfg):
        return ED.encdec_loss(params, batch, cfg)
    return lm_loss(params, batch, cfg)


def model_forward(params, batch, cfg: ModelConfig):
    if _is_encdec(cfg):
        return ED.forward_encdec(params, batch, cfg)
    return forward_lm(params, batch["tokens"], cfg,
                      embeddings=batch.get("embeddings"))


def input_specs(cfg: ModelConfig, *, global_batch: int, seq_len: int,
                kind: str = "train") -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for train_step (kind='train') or the decode
    serve_step's per-step token inputs (kind='decode')."""
    tok = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    if kind == "train":
        specs = {"tokens": tok,
                 "targets": jax.ShapeDtypeStruct((global_batch, seq_len),
                                                 jnp.int32)}
        if _is_encdec(cfg):
            specs["frames"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.encoder_seq, cfg.d_model),
                cfg.adtype())
        if cfg.family == "vlm":
            # chameleon early fusion: VQ image tokens are ordinary vocab ids
            # (stub frontend) — token spec already covers them.
            pass
        return specs
    if kind == "decode":
        specs = {"token": jax.ShapeDtypeStruct((global_batch,), jnp.int32)}
        if _is_encdec(cfg):
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.encoder_seq, cfg.d_model), cfg.adtype())
        return specs
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    return init_lm_decode_state(cfg, batch, max_len)


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int):
    """Shape specs of the decode state WITHOUT allocating it."""
    return jax.eval_shape(
        lambda: init_lm_decode_state(cfg, batch, max_len))


def decode_step(params, state, token, cfg: ModelConfig, *, position,
                enc_out=None):
    return lm_decode_step(params, state, token, cfg, position=position,
                          enc_out=enc_out)
