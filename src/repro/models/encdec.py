"""Encoder-decoder assembly (whisper-small backbone).

Per the assignment the conv/mel frontend is a STUB: `input_specs()` feeds
precomputed frame embeddings [B, n_frames, d_model] to the encoder. The
backbone is real: encoder (noncausal self-attn blocks), decoder (causal
self-attn + cross-attn to encoder output).

FAST applies to all three attention sites: noncausal fastmax (encoder,
cross) and causal fastmax (decoder self) — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    ModelConfig,
    forward_lm,
    init_lm,
    init_lm_decode_state,
    lm_decode_step,
    lm_loss,
    lm_prefill,
)

__all__ = ["encoder_config", "init_encdec", "forward_encdec", "encdec_loss",
           "encode"]


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        n_layers=cfg.encoder_layers,
        first_k_dense=0,
        cross_attention=False,
        input_embeddings_only=True,
        rope_theta=0.0,
        pos_emb="sinusoidal",
    )


def init_encdec(key: jax.Array, cfg: ModelConfig, *, abstract: bool = False):
    k_enc, k_dec = (key, key) if abstract else tuple(jax.random.split(key))
    enc_params, enc_axes = init_lm(k_enc, encoder_config(cfg),
                                   abstract=abstract)
    dec_params, dec_axes = init_lm(k_dec, cfg, abstract=abstract)
    return ({"encoder": enc_params, "decoder": dec_params},
            {"encoder": enc_axes, "decoder": dec_axes})


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, T_enc, d_model] (stub frontend output)."""
    hidden, _ = forward_lm(params["encoder"], None, encoder_config(cfg),
                           causal=False, embeddings=frames,
                           return_hidden=True)
    return hidden


def forward_encdec(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    return forward_lm(params["decoder"], batch["tokens"], cfg,
                      enc_out=enc_out)


def encdec_loss(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    dec_batch = {**batch, "enc_out": enc_out}
    return lm_loss(params["decoder"], dec_batch, cfg)
