"""Fault tolerance: preemption handling, straggler detection, auto-resume."""
from repro.ft.runtime import (  # noqa: F401
    PreemptionHandler,
    StragglerMonitor,
    run_with_restarts,
)
