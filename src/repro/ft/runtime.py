"""Fault-tolerance runtime pieces.

At 1000+ node scale the failure model is: frequent preemptions (spot/
defrag), occasional hard node loss, and slow-node tail latency. The
train loop composes three mechanisms:

  * `PreemptionHandler` — SIGTERM/SIGINT => set a flag; the step loop
    checkpoints and exits cleanly at the next step boundary (checkpoints
    are atomic, so a kill mid-save is also safe).
  * `StragglerMonitor` — robust step-time tracker (median + MAD). A step
    slower than `threshold`x the running median is counted; sustained
    stragglers raise a signal the launcher uses to exclude/replace the
    slow host (on real fleets: report to the cluster scheduler). Also the
    data source for EXPERIMENTS' step-time stats.
  * `run_with_restarts` — supervisor loop: run the step function until
    completion; on worker failure, restore from the last checkpoint and
    continue (elastic: restore reshards to the surviving mesh).
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, Optional

__all__ = ["PreemptionHandler", "StragglerMonitor", "run_with_restarts"]


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._old = {}
        for s in signals:
            try:
                self._old[s] = signal.signal(s, self._on)
            except ValueError:          # not main thread (tests)
                pass

    def _on(self, signum, frame):
        self.requested = True

    def restore(self):
        for s, h in self._old.items():
            signal.signal(s, h)


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50,
                 patience: int = 3):
        self.threshold = threshold
        self.window = window
        self.patience = patience
        self.times: list[float] = []
        self.strikes = 0
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 8 and dt > self.threshold * self.median():
            self.strikes += 1
        else:
            self.strikes = max(0, self.strikes - 1)
        return dt

    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0

    @property
    def straggling(self) -> bool:
        return self.strikes >= self.patience

    def stats(self) -> dict:
        if not self.times:
            return {}
        med = self.median()
        return {"median_s": med,
                "p90_s": sorted(self.times)[int(0.9 * (len(self.times) - 1))],
                "max_s": max(self.times),
                "straggling": self.straggling}


def run_with_restarts(make_state: Callable[[], tuple],
                      run: Callable[..., int],
                      *, max_restarts: int = 10,
                      on_restart: Optional[Callable[[int, Exception], None]]
                      = None) -> int:
    """Supervisor: (re)build state (restoring the latest checkpoint) and run
    until `run` returns normally. Worker exceptions trigger restore+retry —
    the node-failure path of the real launcher, exercised in tests by
    injecting faults."""
    attempt = 0
    while True:
        state = make_state()
        try:
            return run(*state)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
