"""Quickstart: FAST/Fastmax attention in 60 seconds.

1. fastmax as a drop-in attention function,
2. the O(1)-in-context decode state,
3. a tiny fastmax transformer trained for a few steps.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.attention import AttentionSpec, attention, init_state, prefill, step

print("== 1. drop-in attention (one dispatcher, spec picks the operator) ==")
rng = np.random.default_rng(0)
B, H, N, D = 2, 4, 256, 32
q = jnp.asarray(rng.normal(size=(B, H, N, D)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, H, N, D)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, H, N, D)), jnp.float32)

fast = AttentionSpec(family="fastmax", p=2)             # O(N D^3)
soft = AttentionSpec(family="softmax")                  # O(N^2 D)
o_fast = attention(q, k, v, fast, causal=True)
o_soft = attention(q, k, v, soft, causal=True)
print(f"fastmax out {o_fast.shape}, softmax out {o_soft.shape} — "
      f"different metrics, same interface")

print("== 2. constant-size decode state (unified protocol) ==")
state = init_state(fast, batch=B, n_kv_heads=H, q_head_dim=D, v_head_dim=D,
                   max_len=N + 8)
o_pre, state = prefill(q, k, v, fast, state=state)
state_bytes = sum(x.size * x.dtype.itemsize for x in state.moments)
kv_bytes = 2 * B * H * N * D * 4
print(f"fastmax state: {state_bytes/1e6:.2f} MB (CONSTANT in context); "
      f"KV cache at N={N}: {kv_bytes/1e6:.2f} MB (grows with N)")
q1 = jnp.asarray(rng.normal(size=(B, H, 1, D)), jnp.float32)
k1 = jnp.asarray(rng.normal(size=(B, H, 1, D)), jnp.float32)
v1 = jnp.asarray(rng.normal(size=(B, H, 1, D)), jnp.float32)
o1, state = step(state, q1, k1, v1, fast)
print(f"decoded one token: {o1.shape}")

print("== 3. train a tiny fastmax LM ==")
from repro.configs import get_smoke_config
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import init_model

cfg = get_smoke_config("qwen2.5-32b")     # fastmax2 backend by default
params, _ = init_model(jax.random.PRNGKey(0), cfg)
_, opt = pick_optimizer(cfg, 1e6, lr=3e-3, total_steps=40)
opt_state = opt[0](params)
step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
data = SyntheticLM(cfg.vocab_size, seq_len=128, seed=0)
for s in range(40):
    batch = jax.tree.map(jnp.asarray, data.batch(s, 8))
    params, opt_state, m = step(params, opt_state, batch)
    if s % 10 == 0:
        print(f"  step {s:3d}  loss {float(m['loss']):.4f}")
print("done — see examples/train_lm.py for the full driver")
