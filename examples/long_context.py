"""Long-context demo: linear-time prefill on CPU.

Processes a 65k-token document through a small fastmax transformer on CPU —
the paper's headline capability (O(N) attention; softmax at this length
would need ~4096x more attention FLOPs than at 1k and an N^2 matrix).
Prints tokens/sec across context lengths to exhibit the LINEAR scaling, then
decodes from the full-document state.

Run: PYTHONPATH=src python examples/long_context.py [--max-len 65536]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import decode_step, init_decode_state, init_model
from repro.models.transformer import lm_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-len", type=int, default=65536)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen3-1.7b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(lambda p, t, s: lm_prefill(p, t, cfg, s))
    rng = np.random.default_rng(0)

    n = 4096
    while n <= args.max_len:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)),
                           jnp.int32)
        state = init_decode_state(cfg, 1, n + 8)
        t0 = time.monotonic()
        logits, state = prefill(params, toks, state)
        jax.block_until_ready(logits)
        dt = time.monotonic() - t0
        print(f"N={n:7d}: prefill {dt:7.2f}s  ({n/dt:8.0f} tok/s)  "
              f"— linear: tok/s should stay ~flat", flush=True)
        n *= 4

    # decode a few tokens conditioned on the FULL document
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    step = jax.jit(lambda p, s, t, pos: decode_step(p, s, t, cfg,
                                                    position=pos))
    outs = []
    for i in range(8):
        logits_t, state = step(params, state, tok,
                               jnp.asarray(args.max_len + i, jnp.int32))
        tok = jnp.argmax(logits_t, -1).astype(jnp.int32)
        outs.append(int(tok[0]))
    print("decoded continuation from the full-document state:", outs)


if __name__ == "__main__":
    main()
