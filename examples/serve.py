"""Serving example: batched requests, prefill + streaming decode.

Highlights the fastmax serving property: per-sequence state is the moment
tuple — the same size whether the prompt was 100 tokens or 100k tokens.

Run: PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import init_decode_state, init_model
from repro.models.param import tree_bytes

cfg = get_smoke_config("qwen2.5-32b")
params, _ = init_model(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
BATCH, GEN = 4, 24
for prompt_len in (32, 256):
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (BATCH, prompt_len)), jnp.int32)
    state = init_decode_state(cfg, BATCH, prompt_len + GEN)
    t0 = time.monotonic()
    toks = generate(params, cfg, prompts, GEN)
    dt = time.monotonic() - t0
    print(f"prompt={prompt_len:5d}: generated {toks.shape[1]} tok/seq x "
          f"{BATCH} seqs in {dt:.2f}s; decode state "
          f"{tree_bytes(state)/1e6:.2f} MB (constant in prompt length)")
print("sample tokens:", np.asarray(toks[0][:12]))
