"""Serving example: continuous batching with O(1)-in-context slot state.

Four views of the same engine (docs/serving.md):
  1. continuous batching — requests of different lengths admitted into a
     fixed slot pool, chunked prefill interleaved with batched decode;
  2. per-token streaming via `ServeEngine.stream`;
  3. the memory asymmetry — a fastmax slot costs the same bytes at 64 or
     8192 context, while the softmax KV baseline grows linearly;
  4. the fault envelope — every request ends in a terminal RequestStatus
     (cancel() mid-flight, bounded-queue rejection), and engine.stats()
     exposes the lifecycle counters.

Run: PYTHONPATH=src python examples/serve.py
"""
import dataclasses

import jax
import numpy as np

from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.core.decode_state import decode_state_bytes
from repro.models import init_model
from repro.serve import ServeEngine

cfg = get_smoke_config("qwen3-1.7b")
params, _ = init_model(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

# -- 1. continuous batching: staggered requests, one slot pool ------------
eng = ServeEngine(params, cfg, max_slots=3, max_len=128,
                  policy="lpf", prefix_cache_bytes=16 << 20)
rids = [eng.submit(rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                   max_new_tokens=12)
        for n in (40, 17, 65, 23)]            # 4 requests, 3 slots
outs = eng.run()
for rid in rids:
    print(f"request {rid}: {len(outs[rid])} tokens  {outs[rid][:8]}")
for fin in eng.history:
    print(f"  rid {fin.rid}: {fin.status.value:9s} prompt {fin.prompt_len:3d}  "
          f"ttft {fin.ttft * 1e3:6.1f} ms  latency {fin.latency * 1e3:6.1f} ms")

# -- 2. streaming: tokens yielded as the pool produces them ---------------
prompt = rng.integers(0, cfg.vocab_size, 30).astype(np.int32)
print("streamed:", *list(eng.stream(prompt, max_new_tokens=8)))

# -- 3. the point: slot bytes vs context length ---------------------------
soft = dataclasses.replace(cfg, attn=AttentionSpec.parse("softmax"))
print(f"{'ctx':>6} {'fastmax slot':>14} {'softmax slot':>14}")
for ctx in (64, 512, 8192):
    print(f"{ctx:6d} {decode_state_bytes(cfg, 1, ctx):14,d} "
          f"{decode_state_bytes(soft, 1, ctx):14,d}")

# -- 4. the fault envelope: terminal statuses + lifecycle counters --------
from repro.serve import EngineOverloaded

r_cancel = eng.submit(rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                      max_new_tokens=64)
eng.step(); eng.step()                    # mid-decode...
eng.cancel(r_cancel)                      # ...and gone; its slot is free
print(f"cancelled rid {r_cancel}: status={eng.status(r_cancel)}")

tiny = ServeEngine(params, cfg, max_slots=1, max_len=128, max_queue=1)
tiny.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 4)
tiny.step()                               # first request takes the slot
tiny.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 4)
try:                                      # slot busy + queue full
    tiny.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 4)
except EngineOverloaded as e:
    print(f"backpressure: {e}")
tiny.run()
print("stats:", {k: v for k, v in tiny.stats().items() if isinstance(v, int)})
