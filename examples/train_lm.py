"""End-to-end training driver example.

Trains a small-but-real fastmax LM (defaults ~10M params, a few hundred
steps on CPU) with the full production stack: sharding-ready step function,
AdamW, checkpoint/resume, preemption handling, straggler monitoring.

The SAME driver trains the full assigned architectures on a TPU fleet —
swap --smoke for the full config and launch one process per host.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
      PYTHONPATH=src python examples/train_lm.py --resume   # after a kill
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--attn", default="fastmax2",
                    choices=["fastmax1", "fastmax2", "softmax"])
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--smoke", "--steps", str(args.steps),
            "--batch", "16", "--seq", "256", "--lr", "1e-3",
            "--attn", args.attn,
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100"]
    if args.resume:
        argv.append("--resume")
    train_mod.main(argv)


if __name__ == "__main__":
    main()
