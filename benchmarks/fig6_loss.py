"""Fig. 6: training loss curves, softmax vs fastmax1/2, by steps AND by
wall-clock. Paper: per-step parity; per-wallclock fastmax wins at long N."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import init_model


def run(quick: bool = True):
    rows = []
    steps = 40 if quick else 150
    seq = 256 if quick else 1024
    for backend in ("softmax", "fastmax2", "fastmax1"):
        cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                                  attn=AttentionSpec.parse(backend))
        params, _ = init_model(jax.random.PRNGKey(1), cfg)
        _, opt = pick_optimizer(cfg, 1e6, lr=3e-3, total_steps=steps)
        opt_state = opt[0](params)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        data = SyntheticLM(cfg.vocab_size, seq, seed=0)
        t0 = time.perf_counter()
        losses = []
        for s in range(steps):
            batch = jax.tree.map(jnp.asarray, data.batch(s, 4))
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
        wall = time.perf_counter() - t0
        rows.append(csv_row(
            f"fig6/{backend}/N{seq}", wall / steps * 1e6,
            f"loss_first10={np.mean(losses[:10]):.4f};"
            f"loss_last10={np.mean(losses[-10:]):.4f};wall_s={wall:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
