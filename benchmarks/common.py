"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "csv_row", "regression_summary",
           "REGRESSION_THRESHOLD"]

REGRESSION_THRESHOLD = 1.20


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call (jitted fns get compiled in
    warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# cell annotation keys that, when they differ between baseline and fresh,
# make the cell's timings incomparable — the regression check skips the
# suite instead of warning on it:
#   interpret   forced-host-device / off-TPU Pallas cells (Python-loop
#               timings, never comparable to compiled ones)
#   hardware    bench-tpu lane label ("tpu" vs "<platform>-interpret")
#   schedule    the autotuned kernel schedule — a changed schedule changes
#               the measured thing, so the >20% rule can't attribute the
#               delta to a code regression
_LABEL_KEYS = ("interpret", "hardware", "schedule")


def regression_summary(baseline: dict, fresh: dict,
                       tag: str = "bench-json") -> str:
    """One fail-soft line comparing fresh phase timings to the baseline.

    Shared by `benchmarks/run.py` (BENCH_attention.json) and
    `benchmarks/serve_load.py` (BENCH_serve.json). Only `*_us` keys are
    timings; other cell keys are annotations. A suite whose `interpret`,
    `hardware`, or `schedule` label differs from the baseline's is skipped
    entirely: those cells time a different thing (interpret vs compiled,
    other silicon, other kernel schedule), whatever `meta.platform` says.
    """
    if baseline.get("meta", {}).get("platform") != \
            fresh.get("meta", {}).get("platform") or \
            baseline.get("meta", {}).get("quick") != \
            fresh.get("meta", {}).get("quick"):
        return (f"{tag}: baseline platform/mode differs — regression "
                f"check skipped")
    slow, skipped = [], []
    for suite, phases in fresh.get("suites", {}).items():
        base_p = baseline.get("suites", {}).get(suite, {})
        if any(base_p.get(key) != phases.get(key) for key in _LABEL_KEYS):
            skipped.append(suite)
            continue
        for phase, us in phases.items():
            if not phase.endswith("_us"):
                continue
            b = base_p.get(phase)
            if b and us > b * REGRESSION_THRESHOLD:
                slow.append(f"{suite}/{phase[:-3]} {b:.0f}->{us:.0f}us")
    note = (f" (skipped label mismatch: {', '.join(skipped)})"
            if skipped else "")
    if slow:
        return (f"{tag}: WARNING — >20% slower than baseline: "
                + "; ".join(slow) + note)
    return f"{tag}: OK (no >20% regressions vs baseline){note}"
