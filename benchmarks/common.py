"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "csv_row"]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call (jitted fns get compiled in
    warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
