"""Table 1: LRA-lite — expressivity parity of fastmax vs softmax.

The real LRA is a multi-GPU-day benchmark; this is a faithful-in-kind,
CPU-scale stand-in with three of its task archetypes:

  listops   — hierarchical ops over digit tokens (max/min/sum-mod nesting)
  text      — byte-level classification by long-range motif co-occurrence
  image     — flattened pixel-grid classification (orientation of bars)

Same tiny transformer per backend; report accuracy per task. The paper's
claim to validate: fastmax2 ~ softmax (within noise), fastmax1 slightly
behind (Table 1 pattern).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.launch.steps import pick_optimizer
from repro.models import init_model
from repro.models.transformer import forward_lm


# ---------------------------------------------------------------------------
# task generators (deterministic)
# ---------------------------------------------------------------------------


def gen_listops(rng, n, seq):
    """Tokens 0-9 digits; 10=MAX 11=MIN 12=SUMMOD markers placed at random
    segment starts; label = value of the expression tree, 10-way."""
    toks = rng.integers(0, 10, (n, seq))
    ops = rng.integers(10, 13, (n, 4))
    pos = np.sort(rng.integers(0, seq, (n, 4)), axis=1)
    for i in range(n):
        toks[i, pos[i]] = ops[i]
    # label: evaluate segments left->right
    labels = np.zeros(n, np.int64)
    for i in range(n):
        vals = []
        segs = np.split(toks[i], pos[i])
        for seg in segs[1:]:
            digits = seg[1:][seg[1:] < 10]
            if len(digits) == 0:
                continue
            vals.append(int(digits.max()))
        labels[i] = (sum(vals) % 10) if vals else 0
    return toks.astype(np.int32), labels.astype(np.int32)


def gen_text(rng, n, seq, vocab=64):
    """Label = whether motif A appears before motif B (long-range order)."""
    toks = rng.integers(4, vocab, (n, seq))
    labels = rng.integers(0, 2, n)
    for i in range(n):
        pa, pb = sorted(rng.choice(seq - 2, 2, replace=False))
        if labels[i] == 0:
            pa, pb = pb, pa
        toks[i, pa] = 0
        toks[i, pa + 1] = 1
        toks[i, pb] = 2
        toks[i, pb + 1] = 3
    return toks.astype(np.int32), labels.astype(np.int32)


def gen_image(rng, n, side=16):
    """Flattened binary grid; label = bars orientation (H vs V)."""
    labels = rng.integers(0, 2, n)
    imgs = np.zeros((n, side, side), np.int64)
    for i in range(n):
        stripes = rng.integers(2, side // 2)
        idx = rng.choice(side, stripes, replace=False)
        if labels[i] == 0:
            imgs[i, idx, :] = 1
        else:
            imgs[i, :, idx] = 1
    return imgs.reshape(n, side * side).astype(np.int32) + 1, \
        labels.astype(np.int32)


TASKS = {
    "listops": lambda rng, n: gen_listops(rng, n, 128),
    "text": lambda rng, n: gen_text(rng, n, 256),
    "image": lambda rng, n: gen_image(rng, n, 16),
}


def _train_classifier(backend, xtr, ytr, xte, yte, n_classes, steps, seed=0):
    cfg = dataclasses.replace(
        get_smoke_config("qwen2.5-32b"), attn=AttentionSpec.parse(backend),
        vocab_size=int(xtr.max()) + 1, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, chunk_size=64)
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    head = jnp.zeros((cfg.d_model, n_classes))

    def logits_fn(p, head, x):
        hidden, _ = forward_lm(p, x, cfg, causal=False, return_hidden=True)
        return hidden.mean(axis=1) @ head

    def loss_fn(p, head, x, y):
        logp = jax.nn.log_softmax(logits_fn(p, head, x))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    from repro.optim import make_optimizer, warmup_cosine
    init_o, upd = make_optimizer("adamw", warmup_cosine(1e-3, 20, steps),
                                 weight_decay=0.01)
    all_params = {"m": params, "h": head}
    opt = init_o(all_params)

    @jax.jit
    def step(ap, opt, x, y):
        loss, g = jax.value_and_grad(
            lambda a: loss_fn(a["m"], a["h"], x, y))(ap)
        ap, opt = upd(g, opt, ap)
        return ap, opt, loss

    bs = 16
    ntr = xtr.shape[0]
    for s in range(steps):
        i0 = (s * bs) % max(1, ntr - bs)
        ap_x, ap_y = xtr[i0:i0 + bs], ytr[i0:i0 + bs]
        all_params, opt, loss = step(all_params, opt, ap_x, ap_y)

    pred = jnp.argmax(logits_fn(all_params["m"], all_params["h"], xte), -1)
    return float(jnp.mean(pred == yte))


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    n_train = 256 if quick else 2048
    steps = 200 if quick else 600
    tasks = {"text": TASKS["text"], "image": TASKS["image"]} if quick \
        else TASKS
    for task, gen in tasks.items():
        xtr, ytr = gen(rng, n_train)
        xte, yte = gen(rng, 256)
        n_classes = int(max(ytr.max(), yte.max())) + 1
        xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr)
        xte, yte = jnp.asarray(xte), jnp.asarray(yte)
        for backend in ("softmax", "fastmax2", "fastmax1"):
            acc = _train_classifier(backend, xtr, ytr, xte, yte,
                                    n_classes, steps)
            rows.append(csv_row(f"table1/{task}/{backend}", 0.0,
                                f"test_acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
