"""Fig. 2: dropout-variant comparison for Fastmax.

Paper: dropout on the QUADRATIC factorized terms generalizes best (vs
"standard" attention-matrix dropout and "1d" token-dim dropout). Reduced-
scale replica: a single fastmax attention block + linear head trained to
overfit a small synthetic classification set; report train/test accuracy
per variant. "standard" materializes the N^2 matrix (only possible at this
toy scale — that's the paper's point)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.attention import AttentionSpec, attention
from repro.core.ref import fastmax_attention_matrix_ref


def _data(rng, n_samples, seq, vocab, n_classes):
    """One class token (id < n_classes) hidden at a random position in a
    high-id background — attention must retrieve it; small train sets
    overfit, so dropout placement matters (the Fig. 2 question)."""
    toks = rng.integers(n_classes, vocab, (n_samples, seq))
    cls = rng.integers(0, n_classes, n_samples).astype(np.int32)
    pos = rng.integers(0, seq, n_samples)
    toks[np.arange(n_samples), pos] = cls
    return jnp.asarray(toks, jnp.int32), jnp.asarray(cls)


def _apply(params, toks, *, mode, rate, rng_key, train):
    emb = params["emb"][toks]                       # [B, N, d]
    qkv = jnp.einsum("bnd,dhe->bhne", emb, params["qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    if train and mode == "standard" and rate > 0:
        a = fastmax_attention_matrix_ref(q, k, p=2, causal=False)
        keep = jax.random.bernoulli(rng_key, 1 - rate, a.shape)
        a = a * keep / (1 - rate)
        o = jnp.einsum("bhnm,bhme->bhne", a, v)
    else:
        spec = AttentionSpec(
            family="fastmax", p=2, impl="rowwise",
            dropout_rate=rate if train and mode != "standard" else 0.0,
            dropout_mode=mode if mode != "standard" else "quadratic")
        o = attention(q, k, v, spec, causal=False,
                      rng=rng_key if train else None)
    pooled = o.mean(axis=(1, 2))
    return pooled @ params["head"]


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    vocab, seq, d, n_classes = 64, 32, 32, 4
    n_train = 96 if quick else 512
    xtr, ytr = _data(rng, n_train, seq, vocab, n_classes)
    xte, yte = _data(rng, 256, seq, vocab, n_classes)
    steps = 150 if quick else 400

    for mode, rate in [("none", 0.0), ("standard", 0.1), ("1d", 0.1),
                       ("quadratic", 0.1)]:
        kp = jax.random.PRNGKey(0)
        params = {
            "emb": 0.1 * jax.random.normal(kp, (vocab, d)),
            "qkv": 0.3 * jax.random.normal(jax.random.fold_in(kp, 1),
                                           (d, 2, 3 * (d // 2))),
            "head": jnp.zeros((d // 2, n_classes)),
        }

        def loss_fn(p, x, y, key, train=True):
            logits = _apply(p, x, mode=mode, rate=rate, rng_key=key,
                            train=train)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

        grad_fn = jax.jit(jax.value_and_grad(loss_fn),
                          static_argnames=("train",))
        lr = 0.05
        key = jax.random.PRNGKey(7)
        for s in range(steps):
            key, sub = jax.random.split(key)
            _, g = grad_fn(params, xtr, ytr, sub)
            params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)

        def acc(x, y):
            logits = _apply(params, x, mode=mode, rate=rate,
                            rng_key=jax.random.PRNGKey(0), train=False)
            return float(jnp.mean(jnp.argmax(logits, -1) == y))

        rows.append(csv_row(
            f"fig2/dropout_{mode}", 0.0,
            f"train_acc={acc(xtr, ytr):.3f};test_acc={acc(xte, yte):.3f}"))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
