"""Per-phase attention benchmark: prefill / decode / backward per backend.

The perf-trajectory suite behind `BENCH_attention.json` (make bench-json):
one row per (backend, phase) so the prefill, single-token decode, and
training-backward costs of fastmax-kernel vs fastmax-chunked vs softmax are
tracked across PRs. All three phases go through the production surfaces
(`repro.attention` prefill/step protocol + `attention()` dispatcher), so a
routing regression shows up here too.

On CPU the Pallas backends run in interpret mode (REPRO_DECODE_KERNEL=1 is
set for the fastmax-kernel decode row so the kernel path is exercised, not
the jnp fallback) — absolute numbers are only comparable within a machine,
which is exactly what a committed per-repo baseline is for.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import csv_row, time_fn

# hybrid2-kernel: near/far-field backend — prefill/backward go through the
# hybrid Pallas kernel (interpret off-TPU), decode through the two-leg jnp
# state step (moments + rolling window), tracked like every other cell
SPECS = ("softmax", "fastmax2", "fastmax2-kernel", "hybrid2-kernel")

# TP>1 decode cell: the shard_map-wrapped Pallas decode kernel vs the jnp
# feature-TP moment step it replaced as the tensor-parallel serving path.
# Runs in a subprocess so this process keeps its 1-device view: the child
# forces 8 host devices and decodes under a (data=2, model=4) mesh with kv
# heads NOT dividing 'model' (the GQA feature-TP regime of the production
# configs). Interpret-mode kernels — within-machine trend tracking only,
# like every row in this suite.
_TP_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8").strip()
import jax, jax.numpy as jnp, numpy as np
from repro.attention import AttentionSpec, init_state, prefill, step
from repro.launch.mesh import make_test_mesh

b, hq, hkv, n, d, dv, iters, steps = {shape}
spec = AttentionSpec(family="fastmax", p=2, impl="kernel", chunk_size=64)
rng = np.random.default_rng(0)
mkq = lambda m: (jnp.asarray(rng.normal(size=(b, hq, m, d)), jnp.float32),
                 jnp.asarray(rng.normal(size=(b, hkv, m, d)), jnp.float32),
                 jnp.asarray(rng.normal(size=(b, hkv, m, dv)), jnp.float32))
q, k, v = mkq(n)
q1, k1, v1 = mkq(1)
mesh = make_test_mesh((2, 4), ("data", "model"))
from repro.kernels import autotune
res = {{}}
with mesh:
    for key, env in (("decode_us", "1"), ("decode_jnp_us", "0")):
        os.environ["REPRO_DECODE_KERNEL"] = env
        st = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                        v_head_dim=dv, max_len=n + 1)
        _, st = prefill(q, k, v, spec, state=st)
        fn = jax.jit(lambda st, q, k, v: step(st, q, k, v, spec))
        o, _ = fn(st, q1, k1, v1)
        o.block_until_ready()
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            for _ in range(steps):
                o, _ = fn(st, q1, k1, v1)
            o.block_until_ready()
            ts.append((time.perf_counter() - t0) / steps)
        res[key] = min(ts) * 1e6
snap = autotune.snapshot_lookups()
res["schedule"] = {{r["key"]: r["schedule"] for r in snap}}
res["autotune_cache"] = {{r["key"]: r["cache"] for r in snap}}
print(json.dumps(res))
"""


def _bench_tp_decode(*, quick: bool) -> dict:
    shape = ((2, 4, 2, 128, 16, 16, 3, 8) if quick
             else (4, 8, 2, 1024, 64, 64, 5, 16))
    out = subprocess.run(
        [sys.executable, "-c", _TP_SUBPROC.format(shape=shape)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    if out.returncode != 0:
        raise RuntimeError(f"tp-decode subprocess failed: "
                           f"{out.stderr[-800:]}")
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # the subprocess runs on forced HOST devices, so its kernels are
    # interpret-mode even when this process sits on a TPU — label the cell
    # so the regression check never compares it against a compiled-TPU
    # baseline (or vice versa)
    res["interpret"] = True
    res["hardware"] = "cpu-interpret"
    return res


def _mk(rng, b, hq, hkv, n, d, dv, dtype):
    import jax.numpy as jnp
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, n, dv)), dtype)
    return q, k, v


def _bench_spec(name: str, *, b, hq, hkv, n, d, dv, n_steps, iters):
    import jax
    import jax.numpy as jnp
    from repro.attention import (AttentionSpec, attention, init_state,
                                 prefill, step)
    from repro.kernels import autotune

    autotune.clear_lookups()
    spec = AttentionSpec.parse(name)
    rng = np.random.default_rng(0)
    q, k, v = _mk(rng, b, hq, hkv, n, d, dv, jnp.float32)
    q1, k1, v1 = _mk(rng, b, hq, hkv, 1, d, dv, jnp.float32)

    st0 = init_state(spec, batch=b, n_kv_heads=hkv, q_head_dim=d,
                     v_head_dim=dv, max_len=n + n_steps)

    prefill_fn = jax.jit(lambda q, k, v, st: prefill(q, k, v, spec, state=st))
    _, st = prefill_fn(q, k, v, st0)
    t_prefill = time_fn(lambda: prefill_fn(q, k, v, st0)[0], iters=iters)

    step_fn = jax.jit(lambda st, q, k, v: step(st, q, k, v, spec))
    t_decode = time_fn(lambda: step_fn(st, q1, k1, v1)[0], iters=iters)

    grad_fn = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(attention(q, k, v, spec, causal=True)),
        argnums=(0, 1, 2)))
    t_backward = time_fn(lambda: grad_fn(q, k, v), iters=iters)

    res = {
        "prefill_us": t_prefill * 1e6,
        "decode_us": t_decode * 1e6,
        "backward_us": t_backward * 1e6,
    }
    # schedule provenance (kernel cells only — the jnp/softmax suites make
    # no kernel launches and record nothing): the chosen schedule per
    # kernel launch, plus the autotune cache verdict, so perf regressions
    # are attributable to schedule changes and the >20% rule never
    # compares cross-schedule (benchmarks.common.regression_summary)
    snap = autotune.snapshot_lookups()
    if snap:
        res["schedule"] = {r["key"]: r["schedule"] for r in snap}
        res["autotune_cache"] = {r["key"]: r["cache"] for r in snap}
        res["hardware"] = autotune.hardware_label()
    return res


def collect(quick: bool = True) -> dict:
    """Structured results: {meta, suites: {backend: {phase_us: float}}}."""
    import jax

    shape = (dict(b=1, hq=4, hkv=2, n=256, d=16, dv=16, n_steps=4, iters=5)
             if quick else
             dict(b=2, hq=8, hkv=4, n=2048, d=64, dv=64, n_steps=8, iters=5))
    # exercise the native-state decode kernel (interpret off-TPU), not the
    # jnp fallback — this suite tracks the kernel path. The autotuner runs
    # in `offline` mode unless the caller chose one: the committed cache +
    # deterministic cost model pick every schedule (never timing Python
    # loops mid-bench), and each cell records the schedule it ran.
    prev = {var: os.environ.get(var)
            for var in ("REPRO_DECODE_KERNEL", "REPRO_AUTOTUNE")}
    os.environ["REPRO_DECODE_KERNEL"] = "1"
    os.environ.setdefault("REPRO_AUTOTUNE", "offline")
    try:
        suites = {name: _bench_spec(name, **shape) for name in SPECS}
        # TP>1 decode: shard_map kernel vs the jnp feature-TP step
        # (subprocess with 8 forced host devices — inherits the autotune
        # env above so its shard-local lookups record provenance too;
        # fail-soft so a broken child doesn't take the whole suite down)
        try:
            suites["fastmax2-kernel-tp4"] = _bench_tp_decode(quick=quick)
        except Exception as e:  # noqa: BLE001
            print(f"attn_phases: tp-decode cell skipped ({e})",
                  file=sys.stderr)
    finally:
        for var, val in prev.items():
            if val is None:
                os.environ.pop(var, None)
            else:
                os.environ[var] = val
    # off-TPU the Pallas suites run interpret-mode kernel bodies: label the
    # cells so the regression check only ever compares like with like
    # (interpret timings are Python-loop-bound and NOT comparable to either
    # compiled-TPU numbers or the pure-jnp suites' XLA timings)
    if jax.default_backend() != "tpu":
        for name in suites:
            if "kernel" in name:
                suites[name]["interpret"] = True
    return {
        "meta": {
            "platform": jax.default_backend(),
            "quick": quick,
            "shape": shape,
        },
        "suites": suites,
    }


def rows(results: dict):
    """CSV rows for a `collect()` result — the one place the
    `attn_phases/<suite>/<phase>` naming lives."""
    for name, phases in results["suites"].items():
        for phase, us in phases.items():
            if not phase.endswith("_us"):
                continue   # cell annotations (e.g. `interpret`), not timings
            yield csv_row(f"attn_phases/{name}/{phase[:-3]}", us)


def run(quick: bool = True):
    yield from rows(collect(quick=quick))
