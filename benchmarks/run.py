"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Quick mode by default (CPU);
``--full`` runs the paper-scale variants of each.

``--json [PATH]`` additionally runs the per-phase attention suite
(`attention_phases.py`) and writes its structured results (default
``BENCH_attention.json`` — the committed perf baseline). When the output
file already exists it is treated as the baseline: a one-line regression
summary is printed (fail-soft WARNING when any phase is >20% slower on the
same platform) before the file is overwritten.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import regression_summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,table2,fig6,fig2,"
                         "table1,fig4,attn_phases,serve")
    ap.add_argument("--json", nargs="?", const="BENCH_attention.json",
                    default=None, metavar="PATH",
                    help="run the attention phase suite and write its "
                         "structured results (default BENCH_attention.json);"
                         " prints a fail-soft regression summary against "
                         "the existing file")
    ap.add_argument("--require-tpu", action="store_true",
                    help="abort unless running on real TPU silicon — the "
                         "`make bench-tpu` lane, so compiled-hardware "
                         "numbers never get recorded from an interpret-"
                         "mode host by accident")
    args = ap.parse_args()
    quick = not args.full

    if args.require_tpu:
        import jax
        if jax.default_backend() != "tpu":
            sys.exit("bench: --require-tpu but jax.default_backend() is "
                     f"{jax.default_backend()!r} — run this lane on a TPU "
                     "host (the CPU lane is `make bench-json`)")

    from benchmarks import (attention_phases, fig2_dropout, fig3_scaling,
                            fig4_attnmap, fig6_loss, serve_load,
                            table1_lra_lite, table2_throughput)

    suites = {
        "fig3": fig3_scaling.run,
        "table2": table2_throughput.run,
        "fig6": fig6_loss.run,
        "fig2": fig2_dropout.run,
        "table1": table1_lra_lite.run,
        "fig4": fig4_attnmap.run,
        "attn_phases": attention_phases.run,
        "serve": serve_load.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}
    if args.json:
        # the JSON path subsumes the CSV rows of the phase suite
        suites.pop("attn_phases", None)

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn(quick=quick):
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"{name}/elapsed,{(time.time() - t0) * 1e6:.0f},",
              flush=True)

    if args.json:
        fresh = attention_phases.collect(quick=quick)
        for row in attention_phases.rows(fresh):
            print(row, flush=True)
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    baseline = json.load(f)
                print(regression_summary(baseline, fresh, "bench-json"),
                      flush=True)
            except (json.JSONDecodeError, OSError) as e:
                print(f"bench-json: baseline unreadable ({e}) — skipping "
                      f"regression check", file=sys.stderr)
        else:
            print("bench-json: no baseline yet — writing first one",
                  flush=True)
        with open(args.json, "w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"bench-json: wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
