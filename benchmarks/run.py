"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Quick mode by default (CPU);
``--full`` runs the paper-scale variants of each.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,table2,fig6,fig2,"
                         "table1,fig4")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (fig2_dropout, fig3_scaling, fig4_attnmap,
                            fig6_loss, table1_lra_lite, table2_throughput)

    suites = {
        "fig3": fig3_scaling.run,
        "table2": table2_throughput.run,
        "fig6": fig6_loss.run,
        "fig2": fig2_dropout.run,
        "table1": table1_lra_lite.run,
        "fig4": fig4_attnmap.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn(quick=quick):
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"{name}/elapsed,{(time.time() - t0) * 1e6:.0f},",
              flush=True)


if __name__ == "__main__":
    main()
