"""Roofline report: read the dry-run JSONs and print the §Roofline table.

    compute_s    = per-chip matmul FLOPs / 197 TF (bf16)
    memory_s     = per-chip HBM-traffic proxy / 819 GB/s
    collective_s = per-chip collective bytes / 50 GB/s per ICI link

plus MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve) and the
useful-compute ratio MODEL_FLOPS / (chips × HLO_FLOPs) — remat/redundancy
waste shows up here.

Usage: python -m benchmarks.roofline [--dir results/dryrun] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_row(c):
    if "skipped" in c:
        return (f"{c['arch']:18s} {c['shape']:12s} "
                f"SKIP ({c['skipped'][:60]}...)")
    if "error" in c:
        return (f"{c['arch']:18s} {c['shape']:12s} "
                f"FAIL {c['error'][:80]}")
    r = c["roofline"]
    return (f"{c['arch']:18s} {c['shape']:12s} {c['mesh']:8s} "
            f"{c['attn_backend']:9s} "
            f"comp={r['compute_s']:9.3e} mem={r['memory_s']:9.3e} "
            f"coll={r['collective_s']:9.3e} dom={r['dominant']:10s} "
            f"useful={r.get('useful_flops_ratio', 0):6.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    cells = load(args.dir)
    if args.csv:
        print("arch,shape,mesh,attn,compute_s,memory_s,collective_s,"
              "dominant,useful_ratio,status")
        for c in cells:
            if "roofline" in c:
                r = c["roofline"]
                print(f"{c['arch']},{c['shape']},{c['mesh']},"
                      f"{c['attn_backend']},{r['compute_s']:.4e},"
                      f"{r['memory_s']:.4e},{r['collective_s']:.4e},"
                      f"{r['dominant']},"
                      f"{r.get('useful_flops_ratio', 0):.4f},ok")
            else:
                status = "skip" if "skipped" in c else "fail"
                print(f"{c['arch']},{c['shape']},{c.get('mesh','')},,,,,,,"
                      f"{status}")
        return
    for c in cells:
        print(fmt_row(c))


if __name__ == "__main__":
    main()
