"""Serving load generator: continuous batching under Poisson arrivals.

Drives `repro.serve.ServeEngine` with an open-loop workload — Poisson
inter-arrivals (in engine ticks, so runs are deterministic) over a mixture
of prompt lengths — and reports the serving numbers that matter:

  ttft_p50_us / ttft_p95_us   submit -> first token
  tpot_p50_us / tpot_p95_us   per-request mean time per output token
                              (decode portion: (latency - ttft) / (n - 1))
  saturation_tok_s            generated tokens / wall time for the run
  slot_bytes / slot_bytes_4k  per-sequence decode-state bytes at the bench
                              max_len and at a 4k context — CONSTANT for
                              fastmax cells, linear for the softmax-KV
                              baseline (the paper's serving asymmetry)

Cells: softmax-KV baseline, fastmax2-chunked, fastmax2-kernel. Off-TPU the
kernel cell routes decode to the jnp moment fallback and is labeled
`interpret` (not comparable across platforms), matching attention_phases.

A fourth `overload` cell drives a deliberately undersized engine (tiny
slot pool, bounded queue) with arrivals above the service rate and commits
the DEGRADATION counters — admitted / rejected (queue-full backpressure) /
shed (sustained-saturation load shedding) / timed_out / completed — so
regression checks see how the engine fails under pressure, not just
happy-path latency. Arrivals are per-tick, so the counters are exactly
deterministic (no `_us` timings in this cell).

JSON results follow the benchmarks/run.py conventions and are committed as
``BENCH_serve.json``; re-runs print the fail-soft >20% regression summary.

  PYTHONPATH=src python -m benchmarks.serve_load --json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import csv_row

# hybrid2-chunked: the near/far-field backend — slot bytes sit between the
# constant fastmax moments and the linear softmax KV (moments + a fixed
# W-slot window cache, still O(1) in context length)
BACKENDS = ("softmax", "fastmax2-chunked", "fastmax2-kernel",
            "hybrid2-chunked")


def _workload(quick: bool):
    if quick:
        return dict(arch="qwen3-1.7b", n_requests=10, gen=8,
                    prompt_mix=(12, 24, 40), max_len=64, slots=4,
                    mean_interarrival_ticks=2.0,
                    overload=dict(offered=24, per_tick=2, slots=2,
                                  max_queue=4, shed_after=4))
    return dict(arch="qwen3-1.7b", n_requests=32, gen=32,
                prompt_mix=(64, 128, 256), max_len=512, slots=8,
                mean_interarrival_ticks=4.0,
                overload=dict(offered=64, per_tick=2, slots=4,
                              max_queue=8, shed_after=8))


def _bench_backend(spec_name: str, w: dict, *, seed: int = 0) -> dict:
    import jax

    from repro.attention import AttentionSpec
    from repro.configs import get_smoke_config
    from repro.core.decode_state import decode_state_bytes
    from repro.models import init_model
    from repro.serve import ServeEngine

    cfg = get_smoke_config(w["arch"])
    cfg = dataclasses.replace(cfg, attn=AttentionSpec.parse(spec_name))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.choice(w["prompt_mix"])).astype(np.int32)
               for _ in range(w["n_requests"])]
    gaps = rng.exponential(w["mean_interarrival_ticks"], w["n_requests"])
    arrivals = np.floor(np.cumsum(gaps)).astype(int)

    eng = ServeEngine(params, cfg, max_slots=w["slots"],
                      max_len=w["max_len"])

    def drive():
        """Open-loop run: request i is admitted once the engine reaches its
        arrival tick (Poisson in tick-time, so runs are deterministic)."""
        start = eng.tick_count
        i = 0
        while i < len(prompts) or eng.pending:
            while i < len(prompts) and \
                    eng.tick_count - start >= arrivals[i]:
                eng.submit(prompts[i], w["gen"])
                i += 1
            if not eng.pending:
                eng.tick_count += 1   # idle tick: nothing admitted yet
                continue
            eng.step()

    # warmup: the full workload once, so every tick trace (prefill
    # masked/unmasked x decode on/off) is compiled before the timed run
    drive()
    eng.history.clear()
    t0 = time.perf_counter()
    drive()
    wall = time.perf_counter() - t0

    fins = [f for f in eng.history if f.ok]   # terminal-status aware
    ttft = np.sort([f.ttft for f in fins])
    tpot = np.sort([(f.latency - f.ttft) / max(len(f.tokens) - 1, 1)
                    for f in fins])
    n_tok = sum(len(f.tokens) for f in fins)
    pct = lambda a, q: float(np.percentile(a, q)) * 1e6
    return {
        "ttft_p50_us": pct(ttft, 50),
        "ttft_p95_us": pct(ttft, 95),
        "tpot_p50_us": pct(tpot, 50),
        "tpot_p95_us": pct(tpot, 95),
        "saturation_tok_s": n_tok / wall,
        "slot_bytes": decode_state_bytes(cfg, 1, w["max_len"]),
        "slot_bytes_4k": decode_state_bytes(cfg, 1, 4096),
        "n_requests": len(fins),
        "ticks": eng.tick_count,
    }


def _bench_overload(w: dict, *, seed: int = 1) -> dict:
    """Degradation cell: arrivals above the service rate of an undersized
    engine. Tick-based arrivals + no deadlines -> every counter below is
    exactly reproducible run-to-run."""
    import jax

    from repro.attention import AttentionSpec
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.serve import EngineOverloaded, ServeEngine

    o = w["overload"]
    cfg = get_smoke_config(w["arch"])
    cfg = dataclasses.replace(cfg,
                              attn=AttentionSpec.parse("fastmax2-chunked"))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.choice(w["prompt_mix"])).astype(np.int32)
               for _ in range(o["offered"])]

    eng = ServeEngine(params, cfg, max_slots=o["slots"],
                      max_len=w["max_len"], max_queue=o["max_queue"],
                      shed_after=o["shed_after"])
    i = 0
    while i < len(prompts) or eng.pending:
        for _ in range(o["per_tick"]):     # offered rate > service rate
            if i < len(prompts):
                try:
                    eng.submit(prompts[i], w["gen"])
                except EngineOverloaded:
                    pass                   # counted in eng.stats()
                i += 1
        eng.step()

    st = eng.stats()
    return {
        "offered": o["offered"],
        "admitted": st["admitted"],
        "completed": st["finished"],
        "rejected": st["rejected"],
        "shed": st["shed"],
        "timed_out": st["timed_out"],
        "quarantined": st["quarantined"],
        "ticks": st["ticks"],
    }


def collect(quick: bool = True) -> dict:
    """Structured results: {meta, suites: {backend: {metric: value}}}."""
    import jax

    w = _workload(quick)
    suites = {}
    for name in BACKENDS:
        suites[name] = _bench_backend(name, w)
        if "kernel" in name and jax.default_backend() != "tpu":
            # off-TPU the kernel decode path is the jnp fallback — label the
            # cell so regression checks never compare it across platforms
            suites[name]["interpret"] = True
    # degradation counters only (no `_us` keys), so regression_summary
    # reports structure changes without timing comparisons
    suites["overload"] = _bench_overload(w)
    return {
        "meta": {"platform": jax.default_backend(), "quick": quick,
                 "workload": w},
        "suites": suites,
    }


def rows(results: dict):
    for backend, metrics in results["suites"].items():
        if "saturation_tok_s" not in metrics:
            continue   # counters-only cell (overload) has no timings
        tput = metrics["saturation_tok_s"]
        for key, val in metrics.items():
            if key.endswith("_us"):
                yield csv_row(f"serve/{backend}/{key[:-3]}", val,
                              f"{tput:.1f}tok/s")


def run(quick: bool = True):
    """benchmarks.run suite hook."""
    yield from rows(collect(quick=quick))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH")
    args = ap.parse_args()
    fresh = collect(quick=not args.full)
    print("name,us_per_call,derived")
    for row in rows(fresh):
        print(row, flush=True)
    if args.json:
        from benchmarks.common import regression_summary
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    print(regression_summary(json.load(f), fresh,
                                             "bench-serve"),
                          flush=True)
            except (json.JSONDecodeError, OSError) as e:
                print(f"bench-serve: baseline unreadable ({e}) — skipping "
                      f"regression check", file=sys.stderr)
        else:
            print("bench-serve: no baseline yet — writing first one",
                  flush=True)
        with open(args.json, "w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"bench-serve: wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
