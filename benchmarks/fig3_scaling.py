"""Fig. 3: forward-pass wall-clock, Fastmax vs Softmax over N (and D).

Paper result: softmax scales ~N^2, fastmax ~N, break-even N ≈ D^2/4
(second-order). CPU wall-clock here; same asymptotics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.attention import AttentionSpec, attention


def run(quick: bool = True):
    rows = []
    Ns = [256, 512, 1024, 2048] + ([] if quick else [4096, 8192])
    Ds = [16, 32]
    B, H = 1, 4
    rng = np.random.default_rng(0)
    for d in Ds:
        for n in Ns:
            q = jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            k = jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            fns = {
                name: jax.jit(functools.partial(
                    attention, spec=AttentionSpec.parse(name), causal=True))
                for name in ("softmax", "fastmax1", "fastmax2")
            }
            for name, fn in fns.items():
                t = time_fn(fn, q, k, v, warmup=1, iters=3)
                rows.append(csv_row(f"fig3/{name}/D{d}/N{n}", t * 1e6,
                                    f"B{B}xH{H}"))
    # derived: empirical scaling exponents N->2N (largest pair)
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
