"""Render the §Roofline markdown table from dry-run JSONs.

Usage: python -m benchmarks.mktable --dir results/dryrun [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        cells.append(json.load(open(f)))

    print("| arch | shape | mesh | attn | compute_s | memory_s | "
          "collective_s | dominant | useful | arg GB/dev | temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if "skipped" in c:
            print(f"| {c['arch']} | {c['shape']} | — | softmax | — | — | — "
                  f"| SKIP (quadratic @500k) | — | — | — |")
            continue
        if "error" in c:
            print(f"| {c['arch']} | {c['shape']} | {c.get('mesh','')} | | "
                  f"FAIL: {c['error'][:60]} | | | | | | |")
            continue
        r = c["roofline"]
        ma = c["memory_analysis"]
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
              f"{c['attn_backend']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['dominant']} | {r.get('useful_flops_ratio', 0):.3f} | "
              f"{(ma['argument_size'] or 0)/1e9:.2f} | "
              f"{(ma['temp_size'] or 0)/1e9:.2f} |")


if __name__ == "__main__":
    main()
