"""Fig. 4: attention-map structural similarity, Fastmax vs Softmax.

Paper: fastmax's (implicit) attention matrix keeps a structure recognizably
similar to softmax's on the same inputs (strong diagonal for text). We train
a tiny char-LM briefly, then compare the two attention metrics' matrices on
the SAME q/k: report row-wise correlation and diagonal mass.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.core.ref import fastmax_attention_matrix_ref
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import init_model
from repro.models.layers import _project_qkv


def run(quick: bool = True):
    cfg = get_smoke_config("qwen2.5-32b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    _, opt = pick_optimizer(cfg, 1e6, lr=3e-3, total_steps=60)
    opt_state = opt[0](params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab_size, 64, seed=0)
    for s in range(30 if quick else 120):
        batch = jax.tree.map(jnp.asarray, data.batch(s, 8))
        params, opt_state, _ = step_fn(params, opt_state, batch)

    batch = data.batch(999, 2)
    x = params["blocks_0"]  # stacked layers
    emb = params["embed"][jnp.asarray(batch["tokens"])]
    layer0 = jax.tree.map(lambda p: p[0], params["blocks_0"])
    q, k, v = _project_qkv(layer0["mixer"], emb.astype(jnp.float32), cfg,
                           jnp.arange(emb.shape[1]))
    n = q.shape[2]
    # softmax matrix
    s_ = jnp.einsum("bhnd,bhmd->bhnm", q[:, :1], k[:, :1]) / np.sqrt(
        q.shape[-1])
    mask = jnp.tril(jnp.ones((n, n)))
    s_ = jnp.where(mask > 0, s_, -jnp.inf)
    a_soft = jax.nn.softmax(s_, axis=-1)
    a_fast = fastmax_attention_matrix_ref(q[:, :1], k[:, :1], p=2,
                                          causal=True)
    af, as_ = np.asarray(a_fast).ravel(), np.asarray(a_soft).ravel()
    corr = float(np.corrcoef(af, as_)[0, 1])
    diag_soft = float(jnp.mean(jnp.diagonal(a_soft, axis1=-2, axis2=-1)))
    diag_fast = float(jnp.mean(jnp.diagonal(a_fast, axis1=-2, axis2=-1)))
    return [csv_row("fig4/attnmap", 0.0,
                    f"corr={corr:.3f};diag_softmax={diag_soft:.3f};"
                    f"diag_fastmax={diag_fast:.3f}")]


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
