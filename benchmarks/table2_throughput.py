"""Table 2: training steps/sec, Fastmax vs Softmax at the LRA task lengths.

Paper: D=32 per head; break-even for fastmax2 at N=1024; fastmax1 much
faster everywhere. Reduced model width for CPU, same sequence lengths.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.attention import AttentionSpec
from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step, pick_optimizer
from repro.models import init_model


TASK_LENGTHS = {"listops": 2000, "text": 4000, "image": 1000,
                "pathfinder": 1000}


def run(quick: bool = True):
    rows = []
    tasks = {"listops": 2000, "image": 1000} if quick else TASK_LENGTHS
    for task, n in tasks.items():
        for backend in ("softmax", "fastmax2", "fastmax1"):
            cfg = dataclasses.replace(
                get_smoke_config("qwen2.5-32b"),
                attn=AttentionSpec.parse(backend), n_layers=2, d_model=64,
                n_heads=2,
                n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
                chunk_size=128)
            params, _ = init_model(jax.random.PRNGKey(0), cfg)
            _, opt = pick_optimizer(cfg, 1e6)
            opt_state = opt[0](params)
            # no donation: the benchmark re-times the same buffers
            step = jax.jit(make_train_step(cfg, opt))
            rng = np.random.default_rng(0)
            batch = {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (2, n)), jnp.int32),
                "targets": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (2, n)), jnp.int32),
            }

            def stepper(p, o, b):
                p, o, m = step(p, o, b)
                return m["loss"]

            t = time_fn(lambda: stepper(params, opt_state, batch),
                        warmup=1, iters=3)
            rows.append(csv_row(f"table2/{backend}/{task}/N{n}", t * 1e6,
                                f"steps_per_s={1.0 / t:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
