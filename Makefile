PY := PYTHONPATH=src python

.PHONY: test test-fast test-attention test-kernels test-shard test-serve \
	test-faults test-cp test-hybrid dryrun-gate bench bench-json \
	bench-serve bench-tpu ci-fast autotune autotune-check

# full tier-1 suite (everything, incl. multi-minute subprocess compiles)
test:
	$(PY) -m pytest -x -q

# fast verify loop: excludes everything marked `slow` (the ~8-minute
# sharding/dryrun subprocess compiles, e2e driver runs, per-arch
# integration sweeps). ~2 min on a 1-CPU container, dominated by the f64
# operator-equivalence sweeps; the excluded tests still run under `test`.
# Includes the `kernels` marker subset (see test-kernels for just those).
test-fast:
	$(PY) -m pytest -q -m "tier1 and not slow"

# just the attention-operator API (spec/registry/dispatch/decode protocol)
test-attention:
	$(PY) -m pytest -q tests/test_attention_api.py

# just the Pallas kernel validation (fwd/bwd/decode interpret equivalence)
test-kernels:
	$(PY) -m pytest -q -m "kernels and not slow"

# continuous-batching engine tier: slot pool, scheduler, prefix cache, and
# engine-vs-generate() token parity for every decode-capable backend (the
# slow-marked SSM-arch parity sweeps still run under `test`)
test-serve:
	$(PY) -m pytest -q -m "serve and not slow"

# serving chaos tier: deterministic fault injection (NaN-into-slot,
# raising callbacks, burst overload, deadlines, mid-stream cancel, wedged
# ticks) — the engine must fail only the targeted request with the right
# status while unaffected requests stay byte-identical to an undisturbed
# run, and stalls surface as EngineStalled, never silent spins
test-faults:
	$(PY) -m pytest -q -m "faults and not slow"

# multi-device tier: shard_map kernel parity + feature-TP scan grads on 8
# forced host CPU devices (no TPU required; conftest injects XLA_FLAGS)
test-shard:
	REPRO_TEST_DEVICES=8 $(PY) -m pytest -q -m shard tests/test_shard_map.py

# context-parallel tier: seq-mode shard_map training parity (CP=2/4 grads
# vs the single-device kernel, ring vs allgather carry exchange, plan
# selection) on 8 forced host CPU devices
test-cp:
	REPRO_TEST_DEVICES=8 $(PY) -m pytest -q -m cp \
		tests/test_context_parallel.py

# hybrid near/far-field tier: banded-softmax+moments vs the composed
# dense oracle (fwd + grads), window edge cases (w=0 bitwise fastmax,
# w>=N exact softmax), chunked-prefill/decode lockstep, serve parity
test-hybrid:
	$(PY) -m pytest -q -m "hybrid and not slow"

# sharding-health gate: the cells the shard-native work must keep clean —
# 0 involuntary remats on train_4k (feature-TP scan AND the feature-TP
# kernel training path) and decode_32k, decode routed to the shard_map
# Pallas kernels (no jnp fallback), TP=16 training routed to the
# shard_map[feature] Dv-blocked kernels (no chunked-scan fallback), and
# 1M-token context-parallel training (--cp 16) routed shard_map[seq]
# with 0 remats — its cell JSON records the modeled constant-size
# carry-exchange bytes next to the ring-attention O(N*D) alternative;
# hybrid2-kernel training routed shard_map[feature] with 0 remats; and
# whisper-small (12 heads, indivisible by TP=16) proving noncausal
# encoder attention routes the feature-mode kernel wrap, not the
# chunked-scan fallback
dryrun-gate:
	$(PY) -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
		--assert-no-remat --out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
		--attn fastmax2-kernel --assert-no-remat --assert-kernel-route \
		--out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch qwen2.5-32b --shape decode_32k \
		--attn fastmax2-kernel --assert-no-remat --assert-kernel-route \
		--out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch llama3-405b --shape decode_32k \
		--attn softmax --assert-no-remat --out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch qwen3-1.7b --shape train_1M \
		--cp 16 --attn fastmax2-kernel --assert-no-remat \
		--assert-kernel-route --out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
		--attn hybrid2-kernel --assert-no-remat --assert-kernel-route \
		--out results/dryrun-gate
	$(PY) -m repro.launch.dryrun --arch whisper-small --shape train_4k \
		--attn fastmax2-kernel --assert-kernel-route \
		--out results/dryrun-gate

# mirror the CI PR job locally (`.github/workflows/ci.yml` fast tier):
# the seven suites a PR must keep green, in the same order
ci-fast: test-fast test-kernels test-shard test-cp test-serve test-faults \
	test-hybrid

bench:
	$(PY) -m benchmarks.run --quick

# per-phase attention timings -> BENCH_attention.json (the committed perf
# baseline); prints a fail-soft warning when >20% slower than the baseline
bench-json:
	$(PY) -m benchmarks.run --only attn_phases --json BENCH_attention.json

# serving load generator (Poisson arrivals, TTFT/TPOT percentiles,
# saturation tok/s) -> BENCH_serve.json, the committed serving baseline;
# prints the same fail-soft >20% regression summary as bench-json
bench-serve:
	$(PY) -m benchmarks.serve_load --json BENCH_serve.json

# real-hardware bench lane: same suite as bench-json but refuses to run
# off-TPU, tunes on silicon (REPRO_AUTOTUNE=1 measures on cache miss), and
# every kernel cell lands in BENCH_attention.json with hardware="tpu" +
# its measured schedule — never compared against interpret cells
bench-tpu:
	REPRO_AUTOTUNE=1 $(PY) -m benchmarks.run --only attn_phases \
		--json BENCH_attention.json --require-tpu

# regenerate the committed autotune cache (deterministic cost-model
# winners over the dryrun-gate + bench shapes) / check it is not stale —
# the CI autotune job runs the check on every PR
autotune:
	$(PY) -m repro.kernels.autotune --write

autotune-check:
	$(PY) -m repro.kernels.autotune --check
