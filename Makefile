PY := PYTHONPATH=src python

.PHONY: test test-fast test-attention bench

# full tier-1 suite (everything, incl. multi-minute subprocess compiles)
test:
	$(PY) -m pytest -x -q

# fast verify loop: excludes everything marked `slow` (the ~8-minute
# sharding/dryrun subprocess compiles, e2e driver runs, per-arch
# integration sweeps). ~2 min on a 1-CPU container, dominated by the f64
# operator-equivalence sweeps; the excluded tests still run under `test`.
test-fast:
	$(PY) -m pytest -q -m "tier1 and not slow"

# just the attention-operator API (spec/registry/dispatch/decode protocol)
test-attention:
	$(PY) -m pytest -q tests/test_attention_api.py

bench:
	$(PY) -m benchmarks.run --quick
